from .adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from .schedules import constant, warmup_cosine
__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "constant", "warmup_cosine"]
