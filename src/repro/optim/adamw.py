"""AdamW with decoupled weight decay, global-norm clipping and sharded
state (optimizer state inherits the param sharding → ZeRO-style)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float | Callable[[jax.Array], jax.Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
    }


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0
    lr = cfg.lr(step) if callable(cfg.lr) else cfg.lr
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mu_hat = mu / c1
        nu_hat = nu / c2
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
            mu.astype(mu.dtype), nu.astype(nu.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_p, {"step": step, "mu": new_mu, "nu": new_nu}, metrics
