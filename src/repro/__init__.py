"""repro — production-grade JAX framework reproducing "An Efficient
Parallel Algorithm for Computing Determinant of Non-Square Matrices Based
on Radic's Definition" (IJDPS 2015), extended into a multi-pod
training/inference stack.  See DESIGN.md."""

__version__ = "1.0.0"
