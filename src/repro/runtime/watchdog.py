"""Heartbeat watchdog + step-time straggler detector.

The watchdog thread fires ``on_stall`` if no heartbeat arrives within
``timeout_s`` (hung collective / dead host → the launcher checkpoints
what it can and triggers an elastic restart).  The detector keeps an EMA
of step times and flags outliers (persistent stragglers at scale get
their hosts drained; the serving front's drainer sweep and the
autoscaler in ``repro.launch.autoscale`` consume both signals)."""

from __future__ import annotations

import threading
import time
from typing import Callable

__all__ = ["Watchdog", "StepTimer"]


class Watchdog:
    """Fire ``on_stall`` when ``beat()`` goes quiet for ``timeout_s``.

    ``beat()`` is called from whatever thread does the guarded work, the
    deadline check runs on the watchdog's own thread, and ``fired`` is
    read by health probes — so the deadline state is shared three ways
    and lives under ``_lock``.  ``fired`` latches across stalls (a probe
    polling slower than the re-arm period must still see the verdict)
    until ``reset()`` clears it.  ``on_stall`` runs *outside* the lock:
    a handler may ``beat()`` or ``reset()`` without deadlocking.
    """

    # reprolint lock-discipline registry (see DESIGN_LINT.md): the
    # deadline and the latch are written by beat()/reset() callers and
    # the watchdog thread, read by the ``fired`` probe.
    _GUARDED_BY = {"_last": ("_lock",), "_fired": ("_lock",)}

    def __init__(self, timeout_s: float, on_stall: Callable[[], None]):
        self.timeout_s = timeout_s
        self.on_stall = on_stall
        self._lock = threading.Lock()
        self._last = time.monotonic()
        self._stop = threading.Event()
        self._fired = False
        self._t = threading.Thread(target=self._run, daemon=True)

    def start(self):
        self._t.start()
        return self

    def beat(self):
        with self._lock:
            self._last = time.monotonic()

    def reset(self):
        """Clear the ``fired`` latch and re-arm the deadline: one stall
        must not poison every later health check."""
        with self._lock:
            self._fired = False
            self._last = time.monotonic()

    def _run(self):
        while not self._stop.is_set():
            stalled = False
            with self._lock:
                if time.monotonic() - self._last > self.timeout_s:
                    self._fired = True
                    self._last = time.monotonic()  # re-arm
                    stalled = True
            if stalled:
                self.on_stall()  # outside the lock: may beat()/reset()
            time.sleep(self.timeout_s / 10.0)

    @property
    def fired(self) -> bool:
        with self._lock:
            return self._fired

    def stop(self):
        self._stop.set()


class StepTimer:
    """EMA step-time tracker; ``record`` returns True for straggler steps
    (> ``factor`` × EMA after warmup).

    The first sample only *seeds* the EMA — it is calibration, not a
    measurement, so it does not count toward ``n`` or the warmup.
    ``warmup`` is therefore the number of *measured* samples (post-seed
    EMA updates) that must accumulate before detection arms: with
    ``warmup=5`` the seed plus five measured samples pass unflagged and
    the seventh ``record`` is the first eligible straggler.  (The seed
    used to increment ``n``, which shifted the gate by one sample and
    skewed the step ids landing in ``stragglers``.)
    """

    def __init__(self, alpha: float = 0.1, factor: float = 2.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.factor = factor
        self.warmup = warmup
        self.ema: float | None = None
        self.n = 0  # measured samples: records *after* the EMA seed
        self.stragglers: list[int] = []

    def record(self, step: int, dt: float) -> bool:
        if self.ema is None:
            self.ema = dt  # calibration sample: not counted in n
            return False
        self.n += 1
        is_straggler = (self.n > self.warmup
                        and dt > self.factor * self.ema)
        # stragglers don't poison the EMA
        if not is_straggler:
            self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        else:
            self.stragglers.append(step)
        return is_straggler
