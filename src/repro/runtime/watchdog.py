"""Heartbeat watchdog + step-time straggler detector.

The watchdog thread fires ``on_stall`` if no heartbeat arrives within
``timeout_s`` (hung collective / dead host → the launcher checkpoints
what it can and triggers an elastic restart).  The detector keeps an EMA
of step times and flags outliers (persistent stragglers at scale get
their hosts drained; here the signal is logged and tested)."""

from __future__ import annotations

import threading
import time
from typing import Callable

__all__ = ["Watchdog", "StepTimer"]


class Watchdog:
    def __init__(self, timeout_s: float, on_stall: Callable[[], None]):
        self.timeout_s = timeout_s
        self.on_stall = on_stall
        self._last = time.monotonic()
        self._stop = threading.Event()
        self._fired = False
        self._t = threading.Thread(target=self._run, daemon=True)

    def start(self):
        self._t.start()
        return self

    def beat(self):
        self._last = time.monotonic()

    def _run(self):
        while not self._stop.is_set():
            if time.monotonic() - self._last > self.timeout_s:
                self._fired = True
                self.on_stall()
                self._last = time.monotonic()  # re-arm
            time.sleep(self.timeout_s / 10.0)

    @property
    def fired(self) -> bool:
        return self._fired

    def stop(self):
        self._stop.set()


class StepTimer:
    """EMA step-time tracker; ``record`` returns True for straggler steps
    (> ``factor`` × EMA after warmup)."""

    def __init__(self, alpha: float = 0.1, factor: float = 2.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.factor = factor
        self.warmup = warmup
        self.ema: float | None = None
        self.n = 0
        self.stragglers: list[int] = []

    def record(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.ema is None:
            self.ema = dt
            return False
        is_straggler = (self.n > self.warmup
                        and dt > self.factor * self.ema)
        # stragglers don't poison the EMA
        if not is_straggler:
            self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        else:
            self.stragglers.append(step)
        return is_straggler
