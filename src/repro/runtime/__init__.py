from .elastic import MeshPlan, build_mesh, choose_mesh
from .stragglers import run_grains
from .watchdog import StepTimer, Watchdog
__all__ = ["MeshPlan", "build_mesh", "choose_mesh", "run_grains",
           "StepTimer", "Watchdog"]
