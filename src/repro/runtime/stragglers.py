"""Grain scheduler with oversubscription + speculative tail re-execution.

This is the runtime side of the paper's granularity scheme (Section 5):
work = contiguous rank grains of the Radic determinant (or any
embarrassingly-parallel partials).  Policy, mirroring classic
MapReduce-style backup tasks:

* grains are oversubscribed ``grains_per_worker``× so a slow worker holds
  less of the tail;
* when the queue drains, unfinished grains are *speculatively re-issued*
  to idle workers; first completion wins (grain partials are keyed by
  grain id → the reduction is idempotent, duplicates are dropped).

The scheduler is deliberately execution-agnostic (callables in, partials
out) so tests can inject slow/failing workers deterministically.
"""

from __future__ import annotations

import threading
from typing import Callable, Sequence

__all__ = ["run_grains"]


def run_grains(grain_fns: Sequence[Callable[[], float]], n_workers: int,
               *, speculative: bool = True, max_attempts: int = 3,
               fail_on: set[tuple[int, int]] | None = None) -> list:
    """Execute grains on ``n_workers`` threads; returns per-grain results.

    ``max_attempts`` caps how many times one grain may be (re-)issued —
    a grain that fails every attempt surfaces in the terminal error with
    its attempt count instead of exhausting silently.

    ``fail_on``: {(worker_id, grain_id)} attempts that raise (test hook —
    simulates a node dying mid-grain).  With ``speculative=True`` the
    grain is re-issued; otherwise incomplete grains raise.
    """
    if max_attempts < 1:
        raise ValueError("max_attempts must be >= 1")
    n = len(grain_fns)
    results: list = [None] * n
    done = [False] * n
    attempts: list[int] = [0] * n
    lock = threading.Lock()
    fail_on = fail_on or set()

    def next_grain() -> int | None:
        with lock:
            # first pass: unissued grains; speculative pass: unfinished
            for g in range(n):
                if not done[g] and attempts[g] == 0:
                    attempts[g] += 1
                    return g
            if speculative:
                for g in range(n):
                    if not done[g] and attempts[g] < max_attempts:
                        attempts[g] += 1
                        return g
            return None

    def worker(wid: int):
        while True:
            g = next_grain()
            if g is None:
                return
            # the injected-failure check mutates the shared fail_on set,
            # so it happens under the scheduler lock: two workers
            # speculatively attempting the same grain must consume the
            # (wid, g) token exactly once
            with lock:
                fail = (wid, g) in fail_on
                if fail:
                    fail_on.discard((wid, g))
            try:
                if fail:
                    raise RuntimeError(f"simulated failure w{wid} g{g}")
                val = grain_fns[g]()
            except Exception:
                continue  # grain stays unfinished; someone re-issues it
            with lock:
                if not done[g]:       # first completion wins (idempotent)
                    done[g] = True
                    results[g] = val

    threads = [threading.Thread(target=worker, args=(w,))
               for w in range(n_workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if not all(done):
        failed = [f"grain {g} after {attempts[g]} attempt(s)"
                  for g, d in enumerate(done) if not d]
        raise RuntimeError(
            f"grains never completed (max_attempts={max_attempts}): "
            + "; ".join(failed))
    return results
