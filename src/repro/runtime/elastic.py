"""Elastic mesh management + failure handling.

At scale, device loss is routine.  The policy here:

1. the launcher snapshots the healthy device list each restart;
2. :func:`choose_mesh` picks the largest (data × model) grid that fits —
   model parallelism capped by a config knob (TP traffic is ICI-local),
   the remainder goes to data;
3. checkpoints are mesh-agnostic (see ``repro.checkpoint``), so a job
   that lost a pod restarts on the surviving devices with the same
   logical program — re-lowered, re-compiled, re-sharded.

The serving tier reuses the same grid rule one level up:
``repro.launch.autoscale.default_max_workers`` caps the elastic worker
pool at ``choose_mesh(cpu_count, max_model=1).n_devices`` — one serving
worker per data-parallel slot.

Tests simulate failures by restricting the device list.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["choose_mesh", "MeshPlan"]


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    n_devices: int


def _largest_pow2_leq(x: int) -> int:
    p = 1
    while p * 2 <= x:
        p *= 2
    return p


def choose_mesh(n_devices: int, *, max_model: int = 16,
                want_pods: int = 1) -> MeshPlan:
    """Largest usable (pod, data, model) grid for ``n_devices``.

    Uses the largest power-of-two device count (lost nodes rarely leave a
    perfect grid); model axis = min(max_model, what fits); pods only if
    cleanly divisible.
    """
    usable = _largest_pow2_leq(max(1, n_devices))
    model = min(max_model, usable)
    rest = usable // model
    if want_pods > 1 and rest % want_pods == 0 and rest // want_pods >= 1:
        return MeshPlan((want_pods, rest // want_pods, model),
                        ("pod", "data", "model"), usable)
    return MeshPlan((rest, model), ("data", "model"), usable)


def build_mesh(plan: MeshPlan, devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    assert len(devices) >= plan.n_devices, "not enough healthy devices"
    arr = np.array(devices[:plan.n_devices]).reshape(plan.shape)
    return Mesh(arr, plan.axis_names)
