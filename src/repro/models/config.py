"""Model configuration shared by the whole zoo."""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax.numpy as jnp

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention
    rope_theta: float = 10_000.0
    attn_window: int | None = None            # sliding window size
    local_global_period: int | None = None    # gemma2: even layers local
    attn_logit_softcap: float | None = None
    final_logit_softcap: float | None = None
    attn_chunk: int = 0                       # KV-chunked online softmax
    #                                           (flash-style): never holds
    #                                           the full (…,S,T) scores
    act: str = "silu"                         # silu | gelu
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    post_block_norm: bool = False             # gemma2 post-norms
    scale_embeddings: bool = False            # gemma2: embed * sqrt(d)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    dense_residual_ff: int = 0                # arctic parallel dense branch
    capacity_factor: float = 1.25
    moe_impl: str = "onehot"                  # onehot (baseline) | scatter
    moe_group_size: int = 2048                # GShard dispatch group (tokens)

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    ssm_state_dtype: str = "float32"          # decode-state storage dtype

    # hybrid (hymba): parallel attn + ssm heads in each block
    hybrid_heads: bool = False

    # enc-dec / modality frontends (stubs provide precomputed embeddings)
    enc_dec: bool = False
    n_enc_layers: int = 0
    n_frames: int = 1500                      # whisper encoder positions
    n_patches: int = 256                      # vlm prefix length
    prefix_embeds: bool = False               # vlm: image embeds prefix

    # numerics / structure
    param_dtype: str = "float32"
    dtype: str = "bfloat16"                   # activation/compute dtype
    remat: bool = True
    remat_policy: str = "nothing"             # nothing | dots (save matmuls)
    scan_layers: bool = True
    fsdp_over_pod: bool = False               # large models: FSDP over pods
    seq_shard: bool = False                   # sequence-parallel activations
    loss_chunk: int = 0                       # chunked CE (0 = off): never
    #                                           materializes (B,S,V) logits
    cache_update: str = "onehot"              # onehot | dus (decode cache)

    # ---- derived ----
    @property
    def qdim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kvdim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def conv_dim(self) -> int:
        # mamba2 conv runs over [x, B, C] concatenated (n_groups = 1)
        return self.d_inner + 2 * self.ssm_state

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def is_local_layer(self, idx: int) -> bool:
        """gemma2-style alternation: even layers sliding-window."""
        if self.attn_window is None:
            return False
        if self.local_global_period is None:
            return True  # window on every layer
        return idx % self.local_global_period != self.local_global_period - 1

    def validate(self) -> None:
        assert self.qdim > 0 or self.family == "ssm"
        if self.family in ("dense", "vlm", "audio", "hybrid", "moe"):
            assert self.n_heads % self.n_kv_heads == 0
        if self.family == "moe":
            assert self.n_experts > 0 and self.top_k > 0
        if self.family in ("ssm", "hybrid"):
            assert self.ssm_state > 0
            assert self.d_inner % self.ssm_head_dim == 0
        if self.family == "audio":
            assert self.enc_dec and self.n_enc_layers > 0
