"""Shared layer primitives: RMSNorm, RoPE, GLU MLP, initializers."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constraint

__all__ = ["rmsnorm", "rope", "glu_mlp", "init_glu_mlp", "dense_init",
           "ACTS"]

ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
}


def dense_init(key, shape, in_axis: int, dtype) -> jax.Array:
    """Truncated-normal fan-in init (0.02-capped, LLaMA-style)."""
    fan_in = shape[in_axis]
    std = min(0.02, fan_in ** -0.5)
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32)
            * std).astype(dtype)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    """f32 RMS norm with (1 + w) scaling (gemma/llama compatible)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x (..., S, H, D), positions (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin,
                           x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


def init_glu_mlp(key, d_model: int, d_ff: int, dtype, stack: int | None = None
                 ) -> dict:
    ks = jax.random.split(key, 3)
    lead = () if stack is None else (stack,)

    def mk(k, shape, in_axis):
        if stack is None:
            return dense_init(k, shape, in_axis, dtype)
        return jax.vmap(lambda kk: dense_init(kk, shape, in_axis, dtype))(
            jax.random.split(k, stack))

    del lead
    return {
        "w_gate": mk(ks[0], (d_model, d_ff), 0),
        "w_up": mk(ks[1], (d_model, d_ff), 0),
        "w_down": mk(ks[2], (d_ff, d_model), 0),
    }


def glu_mlp(p: dict, x: jax.Array, act: str) -> jax.Array:
    """Gated-linear-unit MLP (SwiGLU / GeGLU by `act`)."""
    h = ACTS[act](x @ p["w_gate"]) * (x @ p["w_up"])
    h = constraint(h, "batch", "seq", "mlp")
    return h @ p["w_down"]
