"""Hymba-style hybrid block: attention heads and SSM heads run in
*parallel* on the same input and are fused by learned per-path gates
(arXiv:2411.13676 §2; meta-tokens stubbed — see DESIGN.md)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import attn_decode, attn_forward, init_attn
from .config import ModelConfig
from .ssm import init_ssm, init_ssm_cache, ssm_decode, ssm_forward

__all__ = ["init_hybrid", "hybrid_forward", "hybrid_decode"]


def init_hybrid(key, cfg: ModelConfig) -> dict:
    ka, ks = jax.random.split(key)
    return {
        "attn": init_attn(ka, cfg),
        "ssm": init_ssm(ks, cfg),
        "gate": jnp.zeros((2,), jnp.float32),  # softmax-ed path weights
    }


def _mix(p, a, s):
    w = jax.nn.softmax(p["gate"])
    return (w[0] * a.astype(jnp.float32)
            + w[1] * s.astype(jnp.float32)).astype(a.dtype)


def hybrid_forward(p, x, cfg: ModelConfig, *, positions, is_local):
    a = attn_forward(p["attn"], x, cfg, positions=positions,
                     is_local=is_local)
    s = ssm_forward(p["ssm"], x, cfg)
    return _mix(p, a, s)


def hybrid_decode(p, x, cache, pos, cfg: ModelConfig, *, is_local):
    """cache = dict(k, v, conv, state) for this layer."""
    a, k, v = attn_decode(p["attn"], x, cache["k"], cache["v"], pos, cfg,
                          is_local=is_local)
    s, conv, state = ssm_decode(p["ssm"], x, cache["conv"], cache["state"],
                                cfg)
    y = _mix(p, a, s)
    return y, {"k": k, "v": v, "conv": conv, "state": state}
