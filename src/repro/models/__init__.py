"""Config-driven model zoo (dense GQA / MoE / SSD / hybrid / enc-dec)."""

from .config import ModelConfig
from .lm import CausalLM
from .encdec import EncDecLM

def build_model(cfg: ModelConfig):
    """Factory: the right model class for a config's family."""
    return EncDecLM(cfg) if cfg.family == "audio" else CausalLM(cfg)

__all__ = ["ModelConfig", "CausalLM", "EncDecLM", "build_model"]
