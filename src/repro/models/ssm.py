"""Mamba-2 SSD (state-space duality) mixer — chunked scan for train/prefill
(sub-quadratic: O(S·chunk) per head) and O(1)-state single-token decode.

Follows the minimal SSD formulation of arXiv:2405.21060 §6 (n_groups=1):
in_proj -> [z | x | B | C | dt]; causal conv over [x|B|C]; SSD; gated
RMSNorm; out_proj.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constraint

from .config import ModelConfig
from .layers import dense_init

__all__ = ["init_ssm", "ssm_forward", "ssm_decode", "init_ssm_cache"]


def init_ssm(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    di = cfg.d_inner
    H = cfg.ssm_heads
    proj_out = 2 * di + 2 * cfg.ssm_state + H  # z, x, B, C, dt
    return {
        "in_proj": dense_init(ks[0], (d, proj_out), 0, cfg.pdtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, cfg.conv_dim), 0,
                             cfg.pdtype),
        "conv_b": jnp.zeros((cfg.conv_dim,), cfg.pdtype),
        "A_log": jnp.zeros((H,), jnp.float32),  # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), 0.5, jnp.float32),
        "norm_w": jnp.zeros((di,), cfg.pdtype),
        "out_proj": dense_init(ks[3], (di, d), 0, cfg.pdtype),
    }


def _split_proj(cfg, proj):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xBC = proj[..., di:di + di + 2 * N]
    dt = proj[..., di + di + 2 * N:]
    assert dt.shape[-1] == H
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv1d. xBC (B,S,C), w (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    return jax.nn.silu(out + b[None, None, :])


def _gated_norm(y, z, w, eps):
    """RMSNorm(y * silu(z)) * (1+w) — mamba2's gated output norm."""
    yf = (y * jax.nn.silu(z)).astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps)
            * (1 + w.astype(jnp.float32))).astype(y.dtype)


def _segsum(a):
    """Causal segment-sum: out[..., l, s] = sum_{s < t <= l} a[..., t].

    a (..., Q); returns (..., Q, Q) with -inf above the diagonal.
    """
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # (..., l, s)
    l_ = jnp.arange(Q)[:, None]
    s_ = jnp.arange(Q)[None, :]
    return jnp.where(l_ >= s_, diff, -jnp.inf)


def ssm_forward(p: dict, x: jax.Array, cfg: ModelConfig,
                return_state: bool = False):
    """Chunked SSD. x (B, S, D) -> (B, S, D).

    ``return_state=True`` additionally returns the prefill cache
    ``{"conv": (B, K-1, conv_dim), "state": (B, H, P, N)}`` so decode can
    continue from position S.
    """
    B, S, D = x.shape
    H, P, N, Q = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                  cfg.ssm_chunk)
    proj = x @ p["in_proj"]
    z, xBC_raw, dt = _split_proj(cfg, proj)
    xBC = _causal_conv(xBC_raw, p["conv_w"].astype(x.dtype),
                       p["conv_b"].astype(x.dtype))
    xs = xBC[..., :cfg.d_inner].reshape(B, S, H, P)
    Bm = xBC[..., cfg.d_inner:cfg.d_inner + N]          # (B,S,N)
    Cm = xBC[..., cfg.d_inner + N:]                     # (B,S,N)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])  # (B,S,H)
    A = -jnp.exp(p["A_log"])                             # (H,)
    dA = dt * A[None, None, :]                           # (B,S,H)

    pad = (-S) % Q
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q
    xs = xs.reshape(B, nc, Q, H, P)
    Bm = Bm.reshape(B, nc, Q, N)
    Cm = Cm.reshape(B, nc, Q, N)
    dA = dA.reshape(B, nc, Q, H)
    dtc = dt.reshape(B, nc, Q, H)
    xdt = xs * dtc[..., None].astype(xs.dtype)           # dt-scaled input

    # --- intra-chunk (quadratic within Q only) ---
    L = jnp.exp(_segsum(jnp.moveaxis(dA, -1, 2)))        # (B,nc,H,Q,Q)
    sc = jnp.einsum("bcln,bcsn->bcls", Cm, Bm)           # (B,nc,Q,Q)
    scL = sc[:, :, None] * L                             # (B,nc,H,l,s)
    y_diag = jnp.einsum("bchls,bcshp->bclhp",
                        scL.astype(xs.dtype), xdt)

    # --- chunk-final states ---
    cum = jnp.cumsum(dA, axis=2)                         # (B,nc,Q,H)
    tot = cum[:, :, -1:, :]                              # (B,nc,1,H)
    decay_out = jnp.exp(tot - cum)                       # to chunk end
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn",
                        Bm, decay_out.astype(xs.dtype), xdt)

    # --- inter-chunk recurrence (scan over chunks) ---
    tot_h = jnp.exp(tot[:, :, 0, :])                     # (B,nc,H)

    def chunk_step(carry, inp):
        st, dec, s_new = carry, inp[0], inp[1]
        nxt = st * dec[:, :, None, None] + s_new
        return nxt, st

    dec_t = jnp.moveaxis(tot_h, 1, 0)                    # (nc,B,H)
    st_t = jnp.moveaxis(states, 1, 0)                    # (nc,B,H,P,N)
    init = jnp.zeros_like(st_t[0])
    final_state, prev = jax.lax.scan(chunk_step, init,
                                     (dec_t.astype(init.dtype), st_t))
    prev = jnp.moveaxis(prev, 0, 1)                      # (B,nc,H,P,N)

    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cm, prev,
                       jnp.exp(cum).astype(xs.dtype))
    y = (y_diag + y_off).reshape(B, Sp, H, P)[:, :S]
    y = y + xs.reshape(B, Sp, H, P)[:, :S] * p["D"][None, None, :, None
                                                    ].astype(y.dtype)
    y = y.reshape(B, S, cfg.d_inner)
    y = constraint(y, "batch", "seq", "inner")
    y = _gated_norm(y, z, p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if not return_state:
        return out
    K = cfg.ssm_conv
    tail = xBC_raw[:, max(0, S - (K - 1)):, :]
    if S < K - 1:
        tail = jnp.pad(tail, ((0, 0), (K - 1 - S, 0), (0, 0)))
    cache = {"conv": tail.astype(cfg.adtype),
             "state": final_state.astype(jnp.dtype(cfg.ssm_state_dtype))}
    return out, cache


def init_ssm_cache(cfg: ModelConfig, batch: int, n_layers: int | None = None):
    L = n_layers if n_layers is not None else cfg.n_layers
    return {
        "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, cfg.conv_dim),
                          cfg.adtype),
        "state": jnp.zeros((L, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                            cfg.ssm_state), jnp.dtype(cfg.ssm_state_dtype)),
    }


def ssm_decode(p: dict, x: jax.Array, conv_state, ssm_state,
               cfg: ModelConfig):
    """One-token decode. x (B,1,D); conv_state (B,K-1,C); ssm_state
    (B,H,P,N) f32.  Returns (out, new_conv_state, new_ssm_state)."""
    B = x.shape[0]
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    proj = x[:, 0] @ p["in_proj"]                        # (B, ...)
    z, xBC, dt = _split_proj(cfg, proj)
    window = jnp.concatenate([conv_state, xBC[:, None, :]], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32))
    xBC = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32)
                      ).astype(x.dtype)
    xs = xBC[:, :cfg.d_inner].reshape(B, H, P)
    Bm = xBC[:, cfg.d_inner:cfg.d_inner + N]
    Cm = xBC[:, cfg.d_inner + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None, :])                        # (B,H)
    upd = jnp.einsum("bhp,bn->bhpn", (xs.astype(jnp.float32)
                                      * dt[..., None]), Bm.astype(jnp.float32))
    new_state = (ssm_state.astype(jnp.float32) * dA[:, :, None, None]
                 + upd).astype(ssm_state.dtype)
    y = jnp.einsum("bhpn,bn->bhp", new_state.astype(jnp.float32),
                   Cm.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B, cfg.d_inner).astype(x.dtype)
    y = _gated_norm(y, z, p["norm_w"], cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None, :]
    return out, window[:, 1:], new_state
