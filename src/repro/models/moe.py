"""Top-k MoE block (grouped GShard one-hot baseline + sort/scatter
optimized path) with optional parallel dense-residual branch (Arctic).

Tokens are dispatched in **groups** (GShard §3.2): the flattened token
stream is cut into groups of ``cfg.moe_group_size`` and every group routes
independently with its own capacity ``C_g = ceil(cf·k·T_g/E)``.  Grouping
keeps the dispatch bookkeeping (cumsum, one-hot, scatter) local to a data
shard — no cross-device prefix sums — and bounds intermediate memory by
``G·T_g·E·C_g`` instead of ``T·E·C``.

Two dispatch implementations, selectable by ``cfg.moe_impl``:

* ``"onehot"`` — classic GShard dispatch einsum, ``2·T·E·C_g·D`` FLOPs.
  Ungrouped this is ~100× the expert matmuls at 128 experts; grouped at
  ``T_g = 2048`` it is only ~20% of them — and the dry-run measurement
  (EXPERIMENTS.md §Perf, arctic-480b) shows its dense einsums partition
  far better than scatter (4.4× fewer HBM bytes, 10× fewer collective
  bytes at ~equal FLOPs), so it is the production winner at this scale.
* ``"scatter"`` — position-in-expert via grouped cumsum + XLA
  scatter/gather: no dispatch matmul FLOPs, but GSPMD partitions the
  scatter/gather poorly on a 2-D mesh (measured: heavy resharding).
  Kept for small-expert / huge-capacity regimes where dispatch einsum
  FLOPs would dominate.

Both drop tokens over capacity (GShard semantics).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constraint

from .config import ModelConfig
from .layers import ACTS, dense_init, init_glu_mlp

__all__ = ["init_moe", "moe_forward"]


def init_moe(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 5)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": dense_init(ks[0], (d, e), 0, jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, f), 1, cfg.pdtype),
        "w_up": dense_init(ks[2], (e, d, f), 1, cfg.pdtype),
        "w_down": dense_init(ks[3], (e, f, d), 1, cfg.pdtype),
    }
    if cfg.dense_residual_ff:
        p["dense"] = init_glu_mlp(ks[4], d, cfg.dense_residual_ff,
                                  cfg.pdtype)
    return p


def _group(cfg: ModelConfig, T: int) -> tuple[int, int, int]:
    """(n_groups, group_size, capacity_per_group)."""
    tg = min(cfg.moe_group_size, T)
    while T % tg:            # shapes here are powers of two in practice
        tg -= 1
    g = T // tg
    c = int(cfg.capacity_factor * cfg.top_k * tg / cfg.n_experts) + 1
    c = min(tg, max(4, -(-c // 4) * 4))
    return g, tg, c


def _router(p, xf, cfg):
    """Router in f32: top-k expert ids + renormalized gates + aux loss."""
    logits = xf.astype(jnp.float32) @ p["router"]      # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)       # (G, Tg, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(idx[..., 0], cfg.n_experts,
                                 dtype=jnp.float32), axis=(0, 1))
    aux = cfg.n_experts * jnp.sum(me * ce)
    return gates, idx, aux


def _expert_glu(p, h, cfg):
    """h (G, E, C, D) -> (G, E, C, D), batched over groups × experts."""
    act = ACTS[cfg.act]
    wg = p["w_gate"].astype(h.dtype)
    wu = p["w_up"].astype(h.dtype)
    wd = p["w_down"].astype(h.dtype)
    g = jnp.einsum("gecd,edf->gecf", h, wg)
    u = jnp.einsum("gecd,edf->gecf", h, wu)
    y = act(g) * u
    y = constraint(y, "batch", "experts", "cap", "mlp")
    return jnp.einsum("gecf,efd->gecd", y, wd)


def _dispatch_onehot(p, x, gates, idx, cfg, C):
    """x (G,Tg,D); the GShard dispatch-einsum baseline."""
    G, Tg, D = x.shape
    E = cfg.n_experts
    oh = jax.nn.one_hot(idx, E, dtype=jnp.int32)            # (G,Tg,k,E)
    flat = oh.reshape(G, Tg * cfg.top_k, E)
    pos = jnp.cumsum(flat, axis=1) * flat                    # 1-based
    pos = pos.reshape(G, Tg, cfg.top_k, E)
    keep = (pos > 0) & (pos <= C)                            # (G,Tg,k,E)
    slot = jnp.clip(pos - 1, 0, C - 1)
    slot_oh = jax.nn.one_hot(slot, C, dtype=x.dtype)         # (G,Tg,k,E,C)
    disp = (slot_oh * keep[..., None].astype(x.dtype)).sum(2)  # (G,Tg,E,C)
    h = jnp.einsum("gtec,gtd->gecd", disp, x)
    y = _expert_glu(p, h, cfg)
    weight = keep.astype(x.dtype) * gates[..., None].astype(x.dtype)
    gate_e = (slot_oh * weight[..., None]).sum(2)            # (G,Tg,E,C)
    return jnp.einsum("gtec,gecd->gtd", gate_e, y)


def _dispatch_scatter(p, x, gates, idx, cfg, C):
    """x (G,Tg,D); grouped sort-free scatter dispatch."""
    G, Tg, D = x.shape
    E = cfg.n_experts
    k = cfg.top_k
    N = Tg * k
    e_flat = idx.reshape(G, N)
    tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Tg, dtype=jnp.int32), k)[None], (G, N))
    g_flat = gates.reshape(G, N).astype(x.dtype)
    oh = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)          # (G,N,E)
    pos = (jnp.cumsum(oh, axis=1) * oh).sum(-1) - 1          # (G,N)
    keep = pos < C
    slot = jnp.where(keep, pos, C)                           # C = overflow
    gidx = jnp.broadcast_to(jnp.arange(G, dtype=jnp.int32)[:, None],
                            (G, N))
    buf = jnp.zeros((G, E, C + 1, D), x.dtype)
    buf = buf.at[gidx, e_flat, slot].set(
        jnp.take_along_axis(x, tok[..., None], axis=1))
    y = _expert_glu(p, buf[:, :, :C], cfg)                   # (G,E,C,D)
    ypad = jnp.concatenate([y, jnp.zeros((G, E, 1, D), y.dtype)], axis=2)
    vals = ypad[gidx, e_flat, slot] * (g_flat
                                       * keep.astype(x.dtype))[..., None]
    out = jnp.zeros((G, Tg, D), x.dtype).at[gidx, tok].add(vals)
    return out


def moe_forward(p: dict, x: jax.Array, cfg: ModelConfig):
    """x (B, S, D) -> ((B, S, D), aux_loss)."""
    B, S, D = x.shape
    T = B * S
    G, Tg, C = _group(cfg, T)
    xg = x.reshape(G, Tg, D)
    xg = constraint(xg, "batch", None, "embed")
    gates, idx, aux = _router(p, xg, cfg)
    if cfg.moe_impl == "scatter":
        y = _dispatch_scatter(p, xg, gates, idx, cfg, C)
    else:
        y = _dispatch_onehot(p, xg, gates, idx, cfg, C)
    y = y.reshape(B, S, D)
    if "dense" in p:  # arctic: parallel dense residual branch
        from .layers import glu_mlp
        y = y + glu_mlp(p["dense"], x, cfg.act)
    return y, aux
