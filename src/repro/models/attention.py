"""Grouped-query attention with sliding-window / softcap options and a
KV-cache decode path.  Pure functions over explicit param dicts; one-layer
granularity (the LM scans over stacked layer params)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constraint

from .config import ModelConfig
from .layers import dense_init, rope

__all__ = ["init_attn", "attn_forward", "attn_decode", "init_kv_cache"]

NEG_INF = -2.0 ** 30  # large-but-finite; avoids NaN rows on fully-masked


def init_attn(key, cfg: ModelConfig, cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        "wq": dense_init(ks[0], (d, cfg.qdim), 0, cfg.pdtype),
        "wk": dense_init(ks[1], (d, cfg.kvdim), 0, cfg.pdtype),
        "wv": dense_init(ks[2], (d, cfg.kvdim), 0, cfg.pdtype),
        "wo": dense_init(ks[3], (cfg.qdim, d), 0, cfg.pdtype),
    }


def _split_heads(x, n_heads, head_dim):
    return x.reshape(*x.shape[:-1], n_heads, head_dim)


def _gqa_scores(q, k, cfg: ModelConfig):
    """q (B,S,H,D), k (B,T,KVH,D) -> scores (B,KVH,G,S,T) in f32."""
    g = cfg.n_heads // cfg.n_kv_heads
    B, S = q.shape[0], q.shape[1]
    qg = q.reshape(B, S, cfg.n_kv_heads, g, cfg.head_dim)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                   preferred_element_type=jnp.float32)
    s = s * (cfg.head_dim ** -0.5)
    if cfg.attn_logit_softcap:
        c = cfg.attn_logit_softcap
        s = c * jnp.tanh(s / c)
    return s


def _softcap_softmax(scores, mask):
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    return w


def _attn_chunked(q, k, v, cfg: ModelConfig, positions, kv_pos, is_local,
                  causal: bool):
    """KV-chunked online-softmax attention (flash-style in pure JAX).

    Scans KV chunks with a running (max, denominator, accumulator), so the
    largest live score buffer is (B,KVH,G,S,chunk) instead of (…,S,T) —
    the §Perf memory lever for long-context training/prefill.  Numerics
    match the unchunked path (f32 running stats).
    """
    B, S = q.shape[0], q.shape[1]
    T = k.shape[1]
    g = cfg.n_heads // cfg.n_kv_heads
    C = min(cfg.attn_chunk, T)
    pad = (-T) % C
    if pad:
        zk = jnp.zeros((B, pad, *k.shape[2:]), k.dtype)
        k = jnp.concatenate([k, zk], axis=1)
        v = jnp.concatenate([v, jnp.zeros_like(zk)], axis=1)
        kv_pos = jnp.concatenate(
            [kv_pos, jnp.full((B, pad), -(10 ** 9), jnp.int32)], axis=1)
    nc = (T + pad) // C
    qg = q.reshape(B, S, cfg.n_kv_heads, g, cfg.head_dim)
    kc = k.reshape(B, nc, C, cfg.n_kv_heads, cfg.head_dim)
    vc = v.reshape(B, nc, C, cfg.n_kv_heads, cfg.head_dim)
    pc = kv_pos.reshape(B, nc, C)
    scale = cfg.head_dim ** -0.5

    def body(carry, inp):
        m_run, l_run, acc = carry          # (B,K,G,S), same, (B,K,G,S,D)
        kb, vb, pb = inp                   # (B,C,K,D), (B,C,K,D), (B,C)
        s = jnp.einsum("bskgd,btkd->bkgst", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        if cfg.attn_logit_softcap:
            cc = cfg.attn_logit_softcap
            s = cc * jnp.tanh(s / cc)
        rel = positions[:, :, None] - pb[:, None, :]       # (B,S,C)
        mask = pb[:, None, :] >= 0
        if causal:
            mask &= rel >= 0
        if cfg.attn_window is not None:
            mask = jnp.where(is_local, mask & (rel < cfg.attn_window),
                             mask)
        s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_run - m_new)
        l_run = l_run * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None].astype(acc.dtype) + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(vb.dtype), vb)
        return (m_new, l_run, acc), None

    K = cfg.n_kv_heads
    init = (jnp.full((B, K, g, S), NEG_INF, jnp.float32),
            jnp.zeros((B, K, g, S), jnp.float32),
            jnp.zeros((B, K, g, S, cfg.head_dim), v.dtype))
    (m_run, l_run, acc), _ = jax.lax.scan(
        body, init, (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
                     jnp.moveaxis(pc, 1, 0)))
    out = acc / jnp.maximum(l_run, 1e-30)[..., None].astype(acc.dtype)
    return jnp.moveaxis(out, 3, 1).reshape(B, S, cfg.qdim)  # (B,S,K,G,D)


def attn_forward(p: dict, x: jax.Array, cfg: ModelConfig, *,
                 positions: jax.Array, is_local, kv: jax.Array | None = None,
                 kv_positions: jax.Array | None = None,
                 causal: bool = True) -> jax.Array:
    """Full-sequence attention (training / prefill / encoder / cross).

    ``kv``: source sequence for cross-attention (defaults to ``x``).
    ``is_local``: traced bool — applies the sliding-window mask (size
    ``cfg.attn_window``) when true; lets scanned layers alternate
    local/global without unrolling.
    """
    src = x if kv is None else kv
    kv_pos = positions if kv_positions is None else kv_positions
    q = _split_heads(x @ p["wq"], cfg.n_heads, cfg.head_dim)
    k = _split_heads(src @ p["wk"], cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(src @ p["wv"], cfg.n_kv_heads, cfg.head_dim)
    if kv is None:  # self-attention gets RoPE
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_pos, cfg.rope_theta)
    q = constraint(q, "batch", "seq", "heads", "head_dim")
    k = constraint(k, "batch", "seq", None, "head_dim")
    if cfg.attn_chunk:
        out = _attn_chunked(q, k, v, cfg, positions, kv_pos, is_local,
                            causal)
        out = constraint(out, "batch", "seq", "qdim")
        return out @ p["wo"]
    scores = _gqa_scores(q, k, cfg)  # (B,KVH,G,S,T)
    rel = positions[:, :, None] - kv_pos[:, None, :]  # (B,S,T)
    mask = jnp.ones_like(rel, dtype=bool)
    if causal:
        mask &= rel >= 0
    if cfg.attn_window is not None:
        local = rel < cfg.attn_window
        win = jnp.where(is_local, mask & local, mask)
        mask = win if causal else mask
    w = _softcap_softmax(scores, mask[:, None, None, :, :])
    g = cfg.n_heads // cfg.n_kv_heads
    out = jnp.einsum("bkgst,btkd->bskgd", w.astype(v.dtype), v)
    out = out.reshape(*x.shape[:-1], cfg.qdim)
    out = constraint(out, "batch", "seq", "qdim")
    return out @ p["wo"]


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  n_layers: int | None = None, dtype=None):
    """Stacked-over-layers KV cache (L, B, T, KVH, D)."""
    L = n_layers if n_layers is not None else cfg.n_layers
    dtype = dtype or cfg.adtype
    shape = (L, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attn_decode(p: dict, x: jax.Array, cache_k, cache_v, pos, cfg: ModelConfig,
                *, is_local, kv_ready: jax.Array | None = None,
                write: bool = True):
    """One-token decode. x (B,1,D); cache_k/v (B,T,KVH,D); pos (B,) int32.

    Returns (out (B,1,D), new_k, new_v).  ``kv_ready`` optionally marks
    cache slots as valid; ``write=False`` reads a static cache without
    RoPE or update (cross-attention memories).
    """
    B = x.shape[0]
    T = cache_k.shape[1]
    q = _split_heads(x @ p["wq"], cfg.n_heads, cfg.head_dim)
    if write:
        k_new = _split_heads(x @ p["wk"], cfg.n_kv_heads, cfg.head_dim)
        v_new = _split_heads(x @ p["wv"], cfg.n_kv_heads, cfg.head_dim)
        q = rope(q, pos[:, None], cfg.rope_theta)
        k_new = rope(k_new, pos[:, None], cfg.rope_theta)
        if cfg.cache_update == "dus":
            # uniform decode position (our serving model): one
            # dynamic_update_slice instead of a (B,T) one-hot multiply —
            # O(B·KVH·D) bytes written vs O(B·T·KVH·D) touched
            cache_k = jax.lax.dynamic_update_slice_in_dim(
                cache_k, k_new.astype(cache_k.dtype), pos[0], axis=1)
            cache_v = jax.lax.dynamic_update_slice_in_dim(
                cache_v, v_new.astype(cache_v.dtype), pos[0], axis=1)
        else:
            # scatter the new token into the cache at pos (per batch row)
            oh = jax.nn.one_hot(pos, T, dtype=cache_k.dtype)  # (B,T)
            cache_k = cache_k * (1 - oh)[:, :, None, None] + \
                oh[:, :, None, None] * k_new.astype(cache_k.dtype)
            cache_v = cache_v * (1 - oh)[:, :, None, None] + \
                oh[:, :, None, None] * v_new.astype(cache_v.dtype)
    cache_k = constraint(cache_k, "batch", "kv_seq", None, "head_dim")
    cache_v = constraint(cache_v, "batch", "kv_seq", None, "head_dim")
    scores = _gqa_scores(q, cache_k, cfg)  # (B,KVH,G,1,T)
    tpos = jnp.arange(T, dtype=jnp.int32)[None, :]  # (1,T)
    mask = tpos <= pos[:, None]
    if kv_ready is not None:
        mask &= kv_ready
    if cfg.attn_window is not None:
        local = tpos > (pos[:, None] - cfg.attn_window)
        mask = jnp.where(is_local, mask & local, mask)
    w = _softcap_softmax(scores, mask[:, None, None, None, :])
    out = jnp.einsum("bkgst,btkd->bskgd", w.astype(cache_v.dtype), cache_v)
    out = out.reshape(B, 1, cfg.qdim)
    return out @ p["wo"], cache_k, cache_v
