"""Modality frontends — STUBS per the assignment.

``[vlm]`` / ``[audio]`` entries specify the transformer backbone only; the
real frontends (InternViT vision tower, Whisper mel+conv stack) are out of
scope.  ``input_specs()`` feeds precomputed patch/frame embeddings, and
these helpers synthesize deterministic stand-ins for tests and examples.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["synthetic_patch_embeds", "synthetic_frame_embeds"]


def synthetic_patch_embeds(key, batch: int, n_patches: int, d_model: int,
                           dtype=jnp.float32) -> jax.Array:
    """Stand-in for the InternViT patch-embedding output (B, P, D)."""
    return jax.random.normal(key, (batch, n_patches, d_model), dtype) * 0.02


def synthetic_frame_embeds(key, batch: int, n_frames: int, d_model: int,
                           dtype=jnp.float32) -> jax.Array:
    """Stand-in for Whisper's conv-downsampled mel frames (B, T, D)."""
    return jax.random.normal(key, (batch, n_frames, d_model), dtype) * 0.02
