"""Unified causal LM over the whole zoo (dense / moe / ssm / hybrid / vlm).

Functional: params are plain pytrees; `CausalLM` holds only the config.
Layers are scanned (stacked leading L dim) with optional remat so the
compiled HLO stays compact for 100+ layer configs.  Every tensor placement
goes through the logical-axis `constraint` helper, so the same code runs
unsharded on CPU and under the production mesh.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import constraint

from .attention import attn_decode, attn_forward, init_attn, init_kv_cache
from .config import ModelConfig
from .hybrid import hybrid_decode, hybrid_forward, init_hybrid
from .layers import dense_init, glu_mlp, init_glu_mlp, rmsnorm
from .moe import init_moe, moe_forward
from .ssm import init_ssm, init_ssm_cache, ssm_decode, ssm_forward

__all__ = ["CausalLM"]


def _norm_shape(d):
    return jnp.zeros((d,), jnp.float32)


class CausalLM:
    def __init__(self, cfg: ModelConfig):
        cfg.validate()
        self.cfg = cfg

    # ------------------------------------------------------------------
    # params
    # ------------------------------------------------------------------
    def _init_layer(self, key) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 3)
        p: dict[str, Any] = {}
        if cfg.family in ("dense", "vlm", "moe", "hybrid"):
            p["norm1"] = _norm_shape(cfg.d_model)
            p["norm2"] = _norm_shape(cfg.d_model)
            if cfg.post_block_norm:
                p["norm1_post"] = _norm_shape(cfg.d_model)
                p["norm2_post"] = _norm_shape(cfg.d_model)
        if cfg.family in ("dense", "vlm"):
            p["attn"] = init_attn(ks[0], cfg)
            p["mlp"] = init_glu_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.pdtype)
        elif cfg.family == "moe":
            p["attn"] = init_attn(ks[0], cfg)
            p["moe"] = init_moe(ks[1], cfg)
        elif cfg.family == "ssm":
            p["norm1"] = _norm_shape(cfg.d_model)
            p["ssm"] = init_ssm(ks[0], cfg)
        elif cfg.family == "hybrid":
            p["mix"] = init_hybrid(ks[0], cfg)
            p["mlp"] = init_glu_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.pdtype)
        else:
            raise ValueError(cfg.family)
        return p

    def init(self, key) -> dict:
        cfg = self.cfg
        k_emb, k_layers, k_head = jax.random.split(key, 3)
        layer_keys = jax.random.split(k_layers, cfg.n_layers)
        layers = jax.vmap(self._init_layer)(layer_keys)
        params = {
            "embed": dense_init(k_emb, (cfg.vocab_size, cfg.d_model), 1,
                                cfg.pdtype),
            "layers": layers,
            "final_norm": _norm_shape(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(
                k_head, (cfg.d_model, cfg.vocab_size), 0, cfg.pdtype)
        return params

    def logical_axes(self) -> dict:
        """Pytree of logical-axis tuples matching init()'s structure."""
        cfg = self.cfg

        def attn_ax():
            return {"wq": ("layers", "embed", "qdim"),
                    "wk": ("layers", "embed", "kvdim"),
                    "wv": ("layers", "embed", "kvdim"),
                    "wo": ("layers", "qdim", "embed")}

        def mlp_ax():
            return {"w_gate": ("layers", "embed", "mlp"),
                    "w_up": ("layers", "embed", "mlp"),
                    "w_down": ("layers", "mlp", "embed")}

        def ssm_ax():
            return {"in_proj": ("layers", "embed", "inner"),
                    "conv_w": ("layers", "conv", None),
                    "conv_b": ("layers", None),
                    "A_log": ("layers", None),
                    "D": ("layers", None),
                    "dt_bias": ("layers", None),
                    "norm_w": ("layers", "inner"),
                    "out_proj": ("layers", "inner", "embed")}

        nrm = ("layers", None)
        lay: dict[str, Any] = {}
        if cfg.family in ("dense", "vlm", "moe", "hybrid"):
            lay["norm1"] = nrm
            lay["norm2"] = nrm
            if cfg.post_block_norm:
                lay["norm1_post"] = nrm
                lay["norm2_post"] = nrm
        if cfg.family in ("dense", "vlm"):
            lay["attn"] = attn_ax()
            lay["mlp"] = mlp_ax()
        elif cfg.family == "moe":
            lay["attn"] = attn_ax()
            moe_ax = {"router": ("layers", "embed", None),
                      "w_gate": ("layers", "experts", "embed", "mlp"),
                      "w_up": ("layers", "experts", "embed", "mlp"),
                      "w_down": ("layers", "experts", "mlp", "embed")}
            if cfg.dense_residual_ff:
                moe_ax["dense"] = mlp_ax()
            lay["moe"] = moe_ax
        elif cfg.family == "ssm":
            lay["norm1"] = nrm
            lay["ssm"] = ssm_ax()
        elif cfg.family == "hybrid":
            lay["mix"] = {"attn": attn_ax(), "ssm": ssm_ax(),
                          "gate": ("layers", None)}
            lay["mlp"] = mlp_ax()
        axes = {
            "embed": ("vocab", "embed"),
            "layers": lay,
            "final_norm": (None,),
        }
        if not cfg.tie_embeddings:
            axes["lm_head"] = ("embed", "vocab")
        return axes

    # ------------------------------------------------------------------
    # forward (train / prefill)
    # ------------------------------------------------------------------
    def _local_flags(self) -> np.ndarray:
        cfg = self.cfg
        return np.array([cfg.is_local_layer(i)
                         for i in range(cfg.n_layers)])

    def _block(self, lp, x, positions, is_local):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        if cfg.family == "ssm":
            x = x + ssm_forward(lp["ssm"], rmsnorm(x, lp["norm1"],
                                                   cfg.norm_eps), cfg)
            return x, aux
        if cfg.family == "hybrid":
            h = hybrid_forward(lp["mix"], rmsnorm(x, lp["norm1"],
                                                  cfg.norm_eps), cfg,
                               positions=positions, is_local=is_local)
            x = x + h
            x = x + glu_mlp(lp["mlp"], rmsnorm(x, lp["norm2"], cfg.norm_eps),
                            cfg.act)
            return x, aux
        # dense / vlm / moe
        a = attn_forward(lp["attn"], rmsnorm(x, lp["norm1"], cfg.norm_eps),
                         cfg, positions=positions, is_local=is_local)
        if cfg.post_block_norm:
            a = rmsnorm(a, lp["norm1_post"], cfg.norm_eps)
        x = x + a
        h_in = rmsnorm(x, lp["norm2"], cfg.norm_eps)
        if cfg.family == "moe":
            h, aux = moe_forward(lp["moe"], h_in, cfg)
        else:
            h = glu_mlp(lp["mlp"], h_in, cfg.act)
        if cfg.post_block_norm:
            h = rmsnorm(h, lp["norm2_post"], cfg.norm_eps)
        x = x + h
        x = constraint(x, "batch", "seq", "embed")
        return x, aux

    def _scan_blocks(self, params, x, positions):
        cfg = self.cfg
        flags = jnp.asarray(self._local_flags())
        block = self._block
        if cfg.remat:
            pol = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                   if cfg.remat_policy == "dots"
                   else jax.checkpoint_policies.nothing_saveable)
            block = jax.checkpoint(block, policy=pol)
        if cfg.scan_layers:
            def step(carry, xs):
                lp, fl = xs
                y, aux = block(lp, carry, positions, fl)
                return y, aux
            x, auxs = jax.lax.scan(step, x, (params["layers"], flags))
            return x, jnp.sum(auxs)
        aux_t = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda p: p[i], params["layers"])
            x, aux = block(lp, x, positions, flags[i])
            aux_t = aux_t + aux
        return x, aux_t

    def _embed(self, params, tokens, prefix_embeds=None):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype)
        if cfg.prefix_embeds:
            assert prefix_embeds is not None, "vlm needs prefix embeds"
            x = jnp.concatenate([prefix_embeds.astype(cfg.adtype), x],
                                axis=1)
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cfg.adtype) \
            if cfg.scale_embeddings else x
        return constraint(x, "batch", "seq", "embed")

    def _head(self, params, x):
        cfg = self.cfg
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        w = (params["embed"].T if cfg.tie_embeddings
             else params["lm_head"])
        logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype),
                            preferred_element_type=jnp.float32)
        if cfg.final_logit_softcap:
            c = cfg.final_logit_softcap
            logits = c * jnp.tanh(logits / c)
        return constraint(logits, "batch", "seq", "vocab")

    def forward(self, params, tokens, prefix_embeds=None):
        """tokens (B,S) -> logits (B, S(+P), V) f32."""
        params = self._cast(params)
        x = self._embed(params, tokens, prefix_embeds)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x, aux = self._scan_blocks(params, x, positions)
        return self._head(params, x), aux

    def loss(self, params, batch):
        """batch: tokens (B,S), labels (B,S) int32 (-1 = ignore)
        [+ prefix_embeds (B,P,D)].  Next-token CE + MoE aux.

        With ``cfg.loss_chunk > 0`` the (B,S,V) logits tensor is never
        materialized: the head matmul + CE run per sequence chunk inside
        a scan (the §Perf memory lever for vocab-heavy configs)."""
        cfg = self.cfg
        labels = batch["labels"]
        if cfg.loss_chunk:
            params_c = self._cast(params)
            x = self._embed(params_c, batch["tokens"],
                            batch.get("prefix_embeds"))
            B, S = x.shape[0], x.shape[1]
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                         (B, S))
            x, aux = self._scan_blocks(params_c, x, positions)
            if cfg.prefix_embeds:
                x = x[:, x.shape[1] - labels.shape[1]:]
            x = rmsnorm(x, params_c["final_norm"], cfg.norm_eps)
            ce = self._ce_chunked(params_c, x[:, :-1], labels[:, 1:])
            return ce + 0.01 * aux
        logits, aux = self.forward(params, batch["tokens"],
                                   batch.get("prefix_embeds"))
        if cfg.prefix_embeds:  # prefix positions carry no labels
            P = logits.shape[1] - labels.shape[1]
            logits = logits[:, P:]
        pred = logits[:, :-1]
        tgt = labels[:, 1:]
        mask = (tgt >= 0).astype(jnp.float32)
        tgt_safe = jnp.maximum(tgt, 0)
        logp = jax.nn.log_softmax(pred, axis=-1)
        ll = jnp.take_along_axis(logp, tgt_safe[..., None],
                                 axis=-1)[..., 0]
        ce = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return ce + 0.01 * aux

    def _ce_chunked(self, params, h, tgt):
        """CE over seq chunks; h (B,T,D) pre-head hidden, tgt (B,T)."""
        cfg = self.cfg
        w = (params["embed"].T if cfg.tie_embeddings
             else params["lm_head"]).astype(h.dtype)
        B, T, D = h.shape
        Q = min(cfg.loss_chunk, T)
        pad = (-T) % Q
        if pad:
            h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
            tgt = jnp.pad(tgt, ((0, 0), (0, pad)), constant_values=-1)
        nc = (T + pad) // Q
        hc = h.reshape(B, nc, Q, D).swapaxes(0, 1)      # (nc,B,Q,D)
        tc = tgt.reshape(B, nc, Q).swapaxes(0, 1)

        def chunk(carry, xs):
            hq, tq = xs
            logits = jnp.einsum("bqd,dv->bqv", hq, w,
                                preferred_element_type=jnp.float32)
            if cfg.final_logit_softcap:
                c = cfg.final_logit_softcap
                logits = c * jnp.tanh(logits / c)
            mask = (tq >= 0).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            picked = jnp.take_along_axis(
                logits, jnp.maximum(tq, 0)[..., None], axis=-1)[..., 0]
            s, n = carry
            return (s + jnp.sum((lse - picked) * mask),
                    n + jnp.sum(mask)), None

        (s, n), _ = jax.lax.scan(chunk, (jnp.zeros((), jnp.float32),
                                         jnp.zeros((), jnp.float32)),
                                 (hc, tc))
        return s / jnp.maximum(n, 1.0)

    def _cast(self, params):
        ad = self.cfg.adtype

        def c(w):
            return w.astype(ad) if (w.dtype == jnp.float32 and w.ndim >= 2
                                    ) else w
        return jax.tree.map(c, params)

    # ------------------------------------------------------------------
    # inference: prefill + decode
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        cache: dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
        if cfg.family != "ssm":
            cache.update(init_kv_cache(cfg, batch, max_len))
        if cfg.family in ("ssm", "hybrid"):
            cache.update(init_ssm_cache(cfg, batch))
        return cache

    def cache_logical_axes(self, cache):
        ax = {"pos": ()}
        if "k" in cache:
            kv = ("layers", "batch", "kv_seq", None, "head_dim")
            ax["k"] = kv
            ax["v"] = kv
        if "conv" in cache:
            ax["conv"] = ("layers", "batch", None, "inner")
            ax["state"] = ("layers", "batch", "ssm_heads", None, "state")
        return ax

    def prefill(self, params, tokens, max_len: int, prefix_embeds=None):
        """Full-sequence forward that also fills the KV/SSM caches.

        Returns (last-position logits (B,V), cache).  The cache holds
        ``max_len`` slots; tokens fill ``[0, S)``.
        """
        cfg = self.cfg
        params = self._cast(params)
        x = self._embed(params, tokens, prefix_embeds)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        flags = jnp.asarray(self._local_flags())

        def step(carry, xs):
            lp, fl = xs
            y, layer_cache = self._prefill_block(lp, carry, positions, fl,
                                                 max_len)
            return y, layer_cache

        if cfg.scan_layers:
            x, caches = jax.lax.scan(step, x, (params["layers"], flags))
        else:  # unrolled (dry-run cost extraction)
            outs = []
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda p: p[i], params["layers"])
                x, lc = self._prefill_block(lp, x, positions, flags[i],
                                            max_len)
                outs.append(lc)
            caches = jax.tree.map(lambda *ls: jnp.stack(ls), *outs)
        logits = self._head(params, x[:, -1:, :])[:, 0]
        caches["pos"] = jnp.asarray(S, jnp.int32)
        return logits, caches

    def _prefill_block(self, lp, x, positions, is_local, max_len):
        cfg = self.cfg
        out: dict[str, Any] = {}
        pad = max_len - x.shape[1]
        if cfg.family == "ssm":
            h, st = ssm_forward(lp["ssm"], rmsnorm(x, lp["norm1"],
                                                   cfg.norm_eps), cfg,
                                return_state=True)
            x = x + h
            out["conv"] = st["conv"]
            out["state"] = st["state"]
            return x, out
        # attention families: run forward, recompute k/v into the cache
        def attn_with_cache(ap, h_in):
            k = (h_in @ ap["wk"]).reshape(*h_in.shape[:-1], cfg.n_kv_heads,
                                          cfg.head_dim)
            v = (h_in @ ap["wv"]).reshape(*h_in.shape[:-1], cfg.n_kv_heads,
                                          cfg.head_dim)
            from .layers import rope
            k = rope(k, positions, cfg.rope_theta)
            kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            return kc.astype(cfg.adtype), vc.astype(cfg.adtype)

        if cfg.family == "hybrid":
            h_in = rmsnorm(x, lp["norm1"], cfg.norm_eps)
            a = attn_forward(lp["mix"]["attn"], h_in, cfg,
                             positions=positions, is_local=is_local)
            s, st = ssm_forward(lp["mix"]["ssm"], h_in, cfg,
                                return_state=True)
            from .hybrid import _mix
            x = x + _mix(lp["mix"], a, s)
            x = x + glu_mlp(lp["mlp"], rmsnorm(x, lp["norm2"], cfg.norm_eps),
                            cfg.act)
            out["k"], out["v"] = attn_with_cache(lp["mix"]["attn"], h_in)
            out["conv"] = st["conv"]
            out["state"] = st["state"]
            return x, out

        h_in = rmsnorm(x, lp["norm1"], cfg.norm_eps)
        a = attn_forward(lp["attn"], h_in, cfg, positions=positions,
                         is_local=is_local)
        if cfg.post_block_norm:
            a = rmsnorm(a, lp["norm1_post"], cfg.norm_eps)
        x = x + a
        h2 = rmsnorm(x, lp["norm2"], cfg.norm_eps)
        if cfg.family == "moe":
            h, _ = moe_forward(lp["moe"], h2, cfg)
        else:
            h = glu_mlp(lp["mlp"], h2, cfg.act)
        if cfg.post_block_norm:
            h = rmsnorm(h, lp["norm2_post"], cfg.norm_eps)
        x = x + h
        out["k"], out["v"] = attn_with_cache(lp["attn"], h_in)
        return x, out

    def decode_step(self, params, cache, tokens):
        """tokens (B,1) -> (logits (B,V), new cache).  One step."""
        cfg = self.cfg
        params = self._cast(params)
        pos = cache["pos"]
        B = tokens.shape[0]
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype)
        if cfg.scale_embeddings:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), cfg.adtype)
        posb = jnp.broadcast_to(pos, (B,))
        flags = jnp.asarray(self._local_flags())

        def step(carry, xs):
            lp, fl, lc = xs
            y, nc = self._decode_block(lp, carry, lc, posb, fl)
            return y, nc

        layer_caches = {k: v for k, v in cache.items() if k != "pos"}
        if cfg.scan_layers:
            x, new_caches = jax.lax.scan(
                step, x, (params["layers"], flags, layer_caches))
        else:  # unrolled (dry-run cost extraction)
            outs = []
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda p: p[i], params["layers"])
                lc = jax.tree.map(lambda c: c[i], layer_caches)
                x, nc = self._decode_block(lp, x, lc, posb, flags[i])
                outs.append(nc)
            new_caches = jax.tree.map(lambda *ls: jnp.stack(ls), *outs)
        logits = self._head(params, x)[:, 0]
        new_caches["pos"] = pos + 1
        return logits, new_caches

    def _decode_block(self, lp, x, lc, pos, is_local):
        cfg = self.cfg
        out: dict[str, Any] = {}
        if cfg.family == "ssm":
            h, conv, state = ssm_decode(lp["ssm"],
                                        rmsnorm(x, lp["norm1"],
                                                cfg.norm_eps),
                                        lc["conv"], lc["state"], cfg)
            out["conv"], out["state"] = conv, state
            return x + h, out
        if cfg.family == "hybrid":
            h_in = rmsnorm(x, lp["norm1"], cfg.norm_eps)
            y, nc = hybrid_decode(lp["mix"], h_in, lc, pos, cfg,
                                  is_local=is_local)
            x = x + y
            x = x + glu_mlp(lp["mlp"], rmsnorm(x, lp["norm2"], cfg.norm_eps),
                            cfg.act)
            return x, nc
        h_in = rmsnorm(x, lp["norm1"], cfg.norm_eps)
        a, k, v = attn_decode(lp["attn"], h_in, lc["k"], lc["v"], pos, cfg,
                              is_local=is_local)
        if cfg.post_block_norm:
            a = rmsnorm(a, lp["norm1_post"], cfg.norm_eps)
        x = x + a
        h2 = rmsnorm(x, lp["norm2"], cfg.norm_eps)
        if cfg.family == "moe":
            h, _ = moe_forward(lp["moe"], h2, cfg)
        else:
            h = glu_mlp(lp["mlp"], h2, cfg.act)
        if cfg.post_block_norm:
            h = rmsnorm(h, lp["norm2_post"], cfg.norm_eps)
        out["k"], out["v"] = k, v
        return x + h, out
