"""Encoder–decoder backbone (Whisper-style).  The conv/mel frontend is a
STUB per the assignment: ``input_specs`` feeds precomputed frame
embeddings (B, n_frames, d_model) straight into the encoder."""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import constraint

from .attention import attn_decode, attn_forward, init_attn, init_kv_cache
from .config import ModelConfig
from .layers import dense_init, glu_mlp, init_glu_mlp, rmsnorm

__all__ = ["EncDecLM"]


class EncDecLM:
    """Whisper-medium-shaped backbone: bidirectional encoder over frame
    embeddings; causal decoder with cross-attention."""

    def __init__(self, cfg: ModelConfig):
        cfg.validate()
        self.cfg = cfg

    # -- params ---------------------------------------------------------
    def _enc_layer(self, key):
        ka, km = jax.random.split(key)
        d = self.cfg.d_model
        return {"attn": init_attn(ka, self.cfg),
                "mlp": init_glu_mlp(km, d, self.cfg.d_ff, self.cfg.pdtype),
                "norm1": jnp.zeros((d,), jnp.float32),
                "norm2": jnp.zeros((d,), jnp.float32)}

    def _dec_layer(self, key):
        ka, kc, km = jax.random.split(key, 3)
        d = self.cfg.d_model
        return {"attn": init_attn(ka, self.cfg),
                "cross": init_attn(kc, self.cfg),
                "mlp": init_glu_mlp(km, d, self.cfg.d_ff, self.cfg.pdtype),
                "norm1": jnp.zeros((d,), jnp.float32),
                "norm2": jnp.zeros((d,), jnp.float32),
                "norm3": jnp.zeros((d,), jnp.float32)}

    def init(self, key):
        cfg = self.cfg
        ke, kd, kv, kh = jax.random.split(key, 4)
        enc = jax.vmap(self._enc_layer)(
            jax.random.split(ke, cfg.n_enc_layers))
        dec = jax.vmap(self._dec_layer)(
            jax.random.split(kd, cfg.n_layers))
        return {
            "embed": dense_init(kv, (cfg.vocab_size, cfg.d_model), 1,
                                cfg.pdtype),
            "enc_layers": enc,
            "dec_layers": dec,
            "enc_norm": jnp.zeros((cfg.d_model,), jnp.float32),
            "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
            "lm_head": dense_init(kh, (cfg.d_model, cfg.vocab_size), 0,
                                  cfg.pdtype),
        }

    def logical_axes(self):
        attn_ax = {"wq": ("layers", "embed", "qdim"),
                   "wk": ("layers", "embed", "kvdim"),
                   "wv": ("layers", "embed", "kvdim"),
                   "wo": ("layers", "qdim", "embed")}
        mlp_ax = {"w_gate": ("layers", "embed", "mlp"),
                  "w_up": ("layers", "embed", "mlp"),
                  "w_down": ("layers", "mlp", "embed")}
        nrm = ("layers", None)
        enc = {"attn": attn_ax, "mlp": mlp_ax, "norm1": nrm, "norm2": nrm}
        dec = {"attn": attn_ax, "cross": dict(attn_ax), "mlp": mlp_ax,
               "norm1": nrm, "norm2": nrm, "norm3": nrm}
        return {"embed": ("vocab", "embed"), "enc_layers": enc,
                "dec_layers": dec, "enc_norm": (None,),
                "final_norm": (None,), "lm_head": ("embed", "vocab")}

    # -- encoder --------------------------------------------------------
    def encode(self, params, frame_embeds):
        cfg = self.cfg
        x = frame_embeds.astype(cfg.adtype)
        x = constraint(x, "batch", "seq", "embed")
        B, T = x.shape[0], x.shape[1]
        pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

        def block(lp, x):
            a = attn_forward(lp["attn"], rmsnorm(x, lp["norm1"],
                                                 cfg.norm_eps), cfg,
                             positions=pos, is_local=False, causal=False)
            x = x + a
            x = x + glu_mlp(lp["mlp"], rmsnorm(x, lp["norm2"],
                                               cfg.norm_eps), cfg.act)
            return x
        if cfg.remat:
            block = jax.checkpoint(
                block, policy=jax.checkpoint_policies.nothing_saveable)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(lambda c, lp: (block(lp, c), None), x,
                                params["enc_layers"])
        else:
            for i in range(cfg.n_enc_layers):
                x = block(jax.tree.map(lambda q: q[i],
                                       params["enc_layers"]), x)
        return rmsnorm(x, params["enc_norm"], cfg.norm_eps)

    # -- decoder (teacher-forced / prefill-style) ------------------------
    def forward(self, params, tokens, frame_embeds):
        cfg = self.cfg
        params = self._cast(params)
        memory = self.encode(params, frame_embeds)
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype)
        B, S = tokens.shape
        T = memory.shape[1]
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        mpos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))

        def block(lp, x):
            a = attn_forward(lp["attn"], rmsnorm(x, lp["norm1"],
                                                 cfg.norm_eps), cfg,
                             positions=pos, is_local=False)
            x = x + a
            c = attn_forward(lp["cross"], rmsnorm(x, lp["norm2"],
                                                  cfg.norm_eps), cfg,
                             positions=pos, is_local=False, kv=memory,
                             kv_positions=mpos, causal=False)
            x = x + c
            x = x + glu_mlp(lp["mlp"], rmsnorm(x, lp["norm3"],
                                               cfg.norm_eps), cfg.act)
            return x
        if cfg.remat:
            block = jax.checkpoint(
                block, policy=jax.checkpoint_policies.nothing_saveable)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(lambda c, lp: (block(lp, c), None), x,
                                params["dec_layers"])
        else:
            for i in range(cfg.n_layers):
                x = block(jax.tree.map(lambda q: q[i],
                                       params["dec_layers"]), x)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["lm_head"].astype(x.dtype),
                            preferred_element_type=jnp.float32)
        return constraint(logits, "batch", "seq", "vocab"), \
            jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        logits, aux = self.forward(params, batch["tokens"],
                                   batch["frame_embeds"])
        tgt = batch["labels"][:, 1:]
        pred = logits[:, :-1]
        mask = (tgt >= 0).astype(jnp.float32)
        logp = jax.nn.log_softmax(pred, axis=-1)
        ll = jnp.take_along_axis(logp, jnp.maximum(tgt, 0)[..., None],
                                 axis=-1)[..., 0]
        return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    def _cast(self, params):
        ad = self.cfg.adtype

        def c(w):
            return w.astype(ad) if (w.dtype == jnp.float32 and w.ndim >= 2
                                    ) else w
        return jax.tree.map(c, params)

    # -- decode ----------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        kv = init_kv_cache(cfg, batch, max_len)
        cross = init_kv_cache(cfg, batch, cfg.n_frames)
        return {"pos": jnp.zeros((), jnp.int32), "k": kv["k"],
                "v": kv["v"], "ck": cross["k"], "cv": cross["v"]}

    def cache_logical_axes(self, cache):
        kv = ("layers", "batch", "kv_seq", None, "head_dim")
        ckv = ("layers", "batch", "frames", None, "head_dim")
        return {"pos": (), "k": kv, "v": kv, "ck": ckv, "cv": ckv}

    def warm_cross_cache(self, params, cache, frame_embeds):
        """Precompute cross-attention K/V from the encoder memory."""
        cfg = self.cfg
        params = self._cast(params)
        memory = self.encode(params, frame_embeds)

        def one(lp):
            k = (memory @ lp["cross"]["wk"]).reshape(
                *memory.shape[:-1], cfg.n_kv_heads, cfg.head_dim)
            v = (memory @ lp["cross"]["wv"]).reshape(
                *memory.shape[:-1], cfg.n_kv_heads, cfg.head_dim)
            return k.astype(cfg.adtype), v.astype(cfg.adtype)

        ck, cv = jax.lax.map(one, params["dec_layers"])
        return dict(cache, ck=ck, cv=cv)

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        params = self._cast(params)
        pos = cache["pos"]
        B = tokens.shape[0]
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.adtype)
        posb = jnp.broadcast_to(pos, (B,))
        T = cache["ck"].shape[2]
        ready = jnp.ones((B, T), bool)

        def step(carry, xs):
            lp, lc = xs
            h = rmsnorm(carry, lp["norm1"], cfg.norm_eps)
            a, k, v = attn_decode(lp["attn"], h, lc["k"], lc["v"], posb,
                                  cfg, is_local=False)
            x = carry + a
            h2 = rmsnorm(x, lp["norm2"], cfg.norm_eps)
            # cross-attn against the precomputed (static) memory cache
            c, _, _ = attn_decode(lp["cross"], h2, lc["ck"], lc["cv"],
                                  jnp.full((B,), T - 1, jnp.int32), cfg,
                                  is_local=False, kv_ready=ready,
                                  write=False)
            x = x + c
            x = x + glu_mlp(lp["mlp"], rmsnorm(x, lp["norm3"],
                                               cfg.norm_eps), cfg.act)
            return x, {"k": k, "v": v}

        lcs = {"k": cache["k"], "v": cache["v"], "ck": cache["ck"],
               "cv": cache["cv"]}
        if cfg.scan_layers:
            x, new_kv = jax.lax.scan(step, x, (params["dec_layers"], lcs))
        else:  # unrolled (dry-run cost extraction)
            outs = []
            for i in range(cfg.n_layers):
                lp = jax.tree.map(lambda q: q[i], params["dec_layers"])
                lc = jax.tree.map(lambda c: c[i], lcs)
                x, nc = step(x, (lp, lc))
                outs.append(nc)
            new_kv = jax.tree.map(lambda *ls: jnp.stack(ls), *outs)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["lm_head"].astype(x.dtype),
                            preferred_element_type=jnp.float32)[:, 0]
        new_cache = dict(cache, k=new_kv["k"], v=new_kv["v"], pos=pos + 1)
        return logits, new_cache
