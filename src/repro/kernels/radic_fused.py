"""Flagship fused Radic-partial Pallas kernel.

One kernel = the paper's whole per-processor pipeline, fused so minors
never touch HBM:

    rank tile ──unrank (VPU, n lane-uniform steps)──► combos (VMEM)
              ──one-hot × Aᵀ (MXU matmul)──────────► minors (VMEM)
              ──pivoted GE (VPU lanes)─────────────► dets
              ──sign · mask · reduce───────────────► f32 partial (VMEM acc)

HBM traffic per tile: *zero* input bytes beyond the replicated A
(m·n·4B) and Pascal table — ranks are generated from the grid index.
Arithmetic intensity is therefore ~(2m²n + ⅔m³ + O(mn)) FLOPs per 0
streamed bytes: firmly compute-bound, the best case for the roofline
(see EXPERIMENTS.md §Perf for the measured terms).

The accumulator uses the sequential-grid guarantee on TPU: grid step 0
zeroes the (1,1) output block, every step adds its partial.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import (batched_det_ge, onehot_gather_minors, onehot_selectors,
                     radic_signs, unrank_tile)

__all__ = ["radic_fused_kernel", "radic_partial_pallas",
           "radic_batched_kernel", "radic_batched_partial_pallas_bygrid",
           "radic_batched_combo_kernel", "radic_batched_partial_pallas",
           "radic_batched_grad_combo_kernel",
           "radic_batched_grad_partial_pallas"]


def radic_fused_kernel(n: int, m: int, tile: int,
                       qinfo_ref, a_ref, table_ref, out_ref):
    pid = pl.program_id(0)
    q_start = qinfo_ref[0]
    count = qinfo_ref[1]
    offs = jax.lax.broadcasted_iota(jnp.int32, (tile, 1), 0)[:, 0]
    offs = pid * tile + offs
    valid = offs < count
    qs = q_start + jnp.where(valid, offs, 0)
    # in-kernel (T, m) unranking; guarded at the ops.py entry points
    combos = unrank_tile(qs, n, m, table_ref[...])  # reprolint: disable=overflow-guard
    A = a_ref[...].astype(jnp.float32)
    minors = onehot_gather_minors(A, combos)                # (T, m, m) MXU
    dets = batched_det_ge(minors)                           # (T,) VPU
    signs = radic_signs(combos, m, dets.dtype)
    part = jnp.sum(jnp.where(valid, signs * dets, 0.0))

    @pl.when(pid == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[0, 0] += part


@functools.partial(jax.jit,
                   static_argnames=("padded_count", "tile", "interpret"))
def radic_partial_pallas(A: jax.Array, table: jax.Array,
                         q_start: jax.Array | int, count: jax.Array | int,
                         padded_count: int, *, tile: int = 256,
                         interpret: bool | None = None) -> jax.Array:
    """Σ sign·det over ranks [q_start, q_start+count); ``padded_count`` is
    the static grid extent (≥ count, tile-aligned)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, n = A.shape
    grid = max(1, -(-padded_count // tile))
    qinfo = jnp.stack([jnp.asarray(q_start, jnp.int32),
                       jnp.asarray(count, jnp.int32)])
    out = pl.pallas_call(
        functools.partial(radic_fused_kernel, n, m, tile),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((m, n), lambda i: (0, 0)),
            pl.BlockSpec((n + 1, m + 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(qinfo, A, table.astype(jnp.int32))
    return out[0, 0].astype(A.dtype)


def radic_batched_kernel(n: int, m: int, tile: int,
                         qinfo_ref, a_ref, table_ref, out_ref):
    """Legacy batched variant: grid (B, num_tiles); block b sees matrix b.

    The rank tile (unranking + signs + selectors) is recomputed per
    (b, tile) cell.  Superseded as the default by
    :func:`radic_batched_combo_kernel`, which hoists that shared work out
    of the batch dimension; this grid is kept as the bit-identity
    reference (``tests/test_kernel_parity.py``) and the benchmark
    baseline the combo kernel is priced against.
    """
    pid = pl.program_id(1)
    q_start = qinfo_ref[0]
    count = qinfo_ref[1]
    offs = jax.lax.broadcasted_iota(jnp.int32, (tile, 1), 0)[:, 0]
    offs = pid * tile + offs
    valid = offs < count
    qs = q_start + jnp.where(valid, offs, 0)
    # in-kernel (T, m) unranking; guarded at the ops.py entry points
    combos = unrank_tile(qs, n, m, table_ref[...])  # reprolint: disable=overflow-guard
    A = a_ref[0].astype(jnp.float32)                        # block (1, m, n)
    minors = onehot_gather_minors(A, combos)                # (T, m, m) MXU
    dets = batched_det_ge(minors)                           # (T,) VPU
    signs = radic_signs(combos, m, dets.dtype)
    part = jnp.sum(jnp.where(valid, signs * dets, 0.0))

    @pl.when(pid == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[0, 0] += part


@functools.partial(jax.jit,
                   static_argnames=("padded_count", "tile", "interpret"))
def radic_batched_partial_pallas_bygrid(As: jax.Array, table: jax.Array,
                                        q_start: jax.Array | int,
                                        count: jax.Array | int,
                                        padded_count: int, *, tile: int = 256,
                                        interpret: bool | None = None
                                        ) -> jax.Array:
    """Legacy (B, num_tiles)-grid batched partial — reference only; the
    serving path dispatches :func:`radic_batched_partial_pallas`."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, m, n = As.shape
    grid = (B, max(1, -(-padded_count // tile)))
    qinfo = jnp.stack([jnp.asarray(q_start, jnp.int32),
                       jnp.asarray(count, jnp.int32)])
    out = pl.pallas_call(
        functools.partial(radic_batched_kernel, n, m, tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda b, i: (0,)),
            pl.BlockSpec((1, m, n), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((n + 1, m + 1), lambda b, i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, i: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.float32),
        interpret=interpret,
    )(qinfo, As, table.astype(jnp.int32))
    return out[:, 0].astype(As.dtype)


def radic_batched_combo_kernel(n: int, m: int, tile: int, batch: int,
                               qinfo_ref, a_ref, table_ref, out_ref):
    """Combo-reuse batched variant: grid (num_tiles,), batch in-kernel.

    Each grid step unranks its rank tile *once*, builds the one-hot
    column selectors and signs once, then contracts the selectors
    against the whole VMEM-resident ``(B, m, n)`` stack in one MXU
    einsum and runs one GE over the flattened ``(B·T, m, m)`` lanes —
    the per-(b, tile) recompute of the legacy grid is gone, so the
    shared VPU work (unranking, selectors, signs) is paid once per tile
    instead of B times.  Per-lane math is unchanged (same contraction
    order over n, same GE steps, same masked per-row reduce over T), so
    results are bit-identical to the legacy grid; the parity tests
    assert exact equality.

    VMEM holds the batch block plus the (B·T, m, m) minor stack — fine
    for serving capacities (``BucketPolicy.max_batch <= 64`` with small
    m); huge B × tile products should shrink ``tile``.
    """
    pid = pl.program_id(0)
    q_start = qinfo_ref[0]
    count = qinfo_ref[1]
    offs = jax.lax.broadcasted_iota(jnp.int32, (tile, 1), 0)[:, 0]
    offs = pid * tile + offs
    valid = offs < count
    qs = q_start + jnp.where(valid, offs, 0)
    # in-kernel (T, m) unranking; guarded at the ops.py entry points
    combos = unrank_tile(qs, n, m, table_ref[...])  # reprolint: disable=overflow-guard
    oh = onehot_selectors(combos, n, jnp.float32)           # (T, m, n) once
    signs = radic_signs(combos, m, jnp.float32)             # (T,) once
    As = a_ref[...].astype(jnp.float32)                     # (B, m, n)
    minors = jnp.einsum("tkn,ban->btka", oh, As,
                        preferred_element_type=jnp.float32)
    dets = batched_det_ge(minors.reshape(batch * tile, m, m))
    dets = dets.reshape(batch, tile)                        # (B, T) VPU
    parts = jnp.sum(jnp.where(valid[None, :], signs[None, :] * dets, 0.0),
                    axis=1)

    @pl.when(pid == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += parts[:, None]


def radic_batched_grad_combo_kernel(n: int, m: int, tile: int, batch: int,
                                    qinfo_ref, a_ref, ct_ref, table_ref,
                                    out_ref):
    """Cofactor-form VJP of the combo-reuse batched kernel.

    Each grid step replays its forward tile exactly — same unranking,
    same one-hot selectors, same signs, same GE lanes — then pulls the
    per-matrix cotangents ``(B,)`` back through that tile's minor-sum
    with ``jax.vjp`` and accumulates ``(B, m, n)`` gradient partials in
    the sequential-grid output block.  The rank walk is shared with the
    forward by construction (DESIGN_GRAD.md): no residual minors cross
    the tile boundary, so backward VMEM is the same O(B·T·m²) as
    forward.
    """
    pid = pl.program_id(0)
    q_start = qinfo_ref[0]
    count = qinfo_ref[1]
    offs = jax.lax.broadcasted_iota(jnp.int32, (tile, 1), 0)[:, 0]
    offs = pid * tile + offs
    valid = offs < count
    qs = q_start + jnp.where(valid, offs, 0)
    # in-kernel (T, m) unranking; guarded at the ops.py entry points
    combos = unrank_tile(qs, n, m, table_ref[...])  # reprolint: disable=overflow-guard
    oh = onehot_selectors(combos, n, jnp.float32)           # (T, m, n) once
    signs = radic_signs(combos, m, jnp.float32)             # (T,) once
    As = a_ref[...].astype(jnp.float32)                     # (B, m, n)
    cts = ct_ref[...].astype(jnp.float32)                   # (B,)

    def tile_partials(a):
        minors = jnp.einsum("tkn,ban->btka", oh, a,
                            preferred_element_type=jnp.float32)
        dets = batched_det_ge(minors.reshape(batch * tile, m, m))
        dets = dets.reshape(batch, tile)                    # (B, T)
        return jnp.sum(
            jnp.where(valid[None, :], signs[None, :] * dets, 0.0), axis=1)

    _, pull = jax.vjp(tile_partials, As)
    (gAs,) = pull(cts)

    @pl.when(pid == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += gAs


@functools.partial(jax.jit,
                   static_argnames=("padded_count", "tile", "interpret"))
def radic_batched_grad_partial_pallas(As: jax.Array, cts: jax.Array,
                                      table: jax.Array,
                                      q_start: jax.Array | int,
                                      count: jax.Array | int,
                                      padded_count: int, *, tile: int = 256,
                                      interpret: bool | None = None
                                      ) -> jax.Array:
    """Gradient partial over ranks [q_start, q_start+count): pulls the
    per-matrix cotangents ``cts (B,)`` back through the rank range for a
    stack ``As (B, m, n)`` -> ``(B, m, n)``."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, m, n = As.shape
    grid = (max(1, -(-padded_count // tile)),)
    qinfo = jnp.stack([jnp.asarray(q_start, jnp.int32),
                       jnp.asarray(count, jnp.int32)])
    out = pl.pallas_call(
        functools.partial(radic_batched_grad_combo_kernel, n, m, tile, B),
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((B, m, n), lambda i: (0, 0, 0)),
            pl.BlockSpec((B,), lambda i: (0,)),
            pl.BlockSpec((n + 1, m + 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((B, m, n), lambda i: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, m, n), jnp.float32),
        interpret=interpret,
    )(qinfo, As, cts, table.astype(jnp.int32))
    return out.astype(As.dtype)


@functools.partial(jax.jit,
                   static_argnames=("padded_count", "tile", "interpret"))
def radic_batched_partial_pallas(As: jax.Array, table: jax.Array,
                                 q_start: jax.Array | int,
                                 count: jax.Array | int,
                                 padded_count: int, *, tile: int = 256,
                                 interpret: bool | None = None) -> jax.Array:
    """Per-matrix Σ sign·det over ranks [q_start, q_start+count) for a
    shape-uniform stack ``As (B, m, n)`` -> ``(B,)``.

    Dispatches the combo-reuse kernel (tile in the grid axis, batch in a
    VMEM-resident in-kernel loop); bit-identical to the legacy
    ``(B, num_tiles)`` grid of :func:`radic_batched_partial_pallas_bygrid`.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, m, n = As.shape
    grid = (max(1, -(-padded_count // tile)),)
    qinfo = jnp.stack([jnp.asarray(q_start, jnp.int32),
                       jnp.asarray(count, jnp.int32)])
    out = pl.pallas_call(
        functools.partial(radic_batched_combo_kernel, n, m, tile, B),
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((B, m, n), lambda i: (0, 0, 0)),
            pl.BlockSpec((n + 1, m + 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((B, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.float32),
        interpret=interpret,
    )(qinfo, As, table.astype(jnp.int32))
    return out[:, 0].astype(As.dtype)
