"""Batched combinatorial-addition (unranking) Pallas kernel.

Grid over rank tiles; the Pascal table (``(n+1)·(m+1)·4B`` — a few KiB)
is replicated into VMEM for every grid step, the walk runs ``n``
lane-uniform iterations (see DESIGN.md §2).  int32 ranks — callers must
keep ``C(n, m) < 2³¹`` per shard (the distributed grain mode covers the
rest of the range).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import unrank_tile

__all__ = ["unrank_kernel", "unrank_pallas"]


def unrank_kernel(n: int, m: int, q_ref, table_ref, out_ref):
    # in-kernel unranking; guarded at the ops.py entry point
    out_ref[...] = unrank_tile(q_ref[...], n, m, table_ref[...])  # reprolint: disable=overflow-guard


@functools.partial(jax.jit,
                   static_argnames=("n", "m", "tile", "interpret"))
def unrank_pallas(qs: jax.Array, n: int, m: int, table: jax.Array, *,
                  tile: int = 256, interpret: bool | None = None
                  ) -> jax.Array:
    """``qs (B,) int32 -> combos (B, m) int32`` (1-indexed)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    qs = qs.astype(jnp.int32)
    B = qs.shape[0]
    pad = (-B) % tile
    if pad:
        qs = jnp.concatenate([qs, jnp.zeros((pad,), jnp.int32)])
    Bp = qs.shape[0]
    out = pl.pallas_call(
        functools.partial(unrank_kernel, n, m),
        grid=(Bp // tile,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((n + 1, m + 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, m), jnp.int32),
        interpret=interpret,
    )(qs, table.astype(jnp.int32))
    return out[:B]
