"""jit'd public wrappers around the Pallas kernels (tiling/padding policy,
interpret-mode fallback on non-TPU backends, dtype policy)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import validate_rank_space
from repro.core.pascal import binom_table

from .minor_det import minor_det_pallas
from .radic_fused import (radic_batched_grad_partial_pallas,
                          radic_batched_partial_pallas,
                          radic_batched_partial_pallas_bygrid,
                          radic_partial_pallas)
from .unrank_kernel import unrank_pallas

__all__ = ["minor_det", "unrank", "radic_partial_pallas",
           "radic_det_pallas", "radic_batched_partial_pallas",
           "radic_det_batched_pallas", "radic_det_batched_pallas_bygrid",
           "radic_det_grad_pallas", "radic_det_batched_grad_pallas"]


def minor_det(mats: jax.Array, *, tile: int = 128,
              interpret: bool | None = None) -> jax.Array:
    """Batched determinant of ``(B, m, m)`` minors."""
    return minor_det_pallas(mats, tile=tile, interpret=interpret)


def unrank(qs: jax.Array, n: int, m: int, *, tile: int = 256,
           interpret: bool | None = None) -> jax.Array:
    """Batched rank → 1-indexed combination."""
    # same plan-time guard as the det wrappers: the kernel's int32 rank
    # arithmetic is a hard limit, and an unguarded table would wrap
    validate_rank_space(m, n, backend="pallas")
    table = jnp.asarray(binom_table(n, m, dtype=np.int32))
    return unrank_pallas(qs, n, m, table, tile=tile, interpret=interpret)


def radic_det_pallas(A: jax.Array, q_start: int = 0, count: int | None = None,
                     *, tile: int = 256,
                     interpret: bool | None = None) -> jax.Array:
    """Radic determinant (or a rank-range partial) via the fused kernel."""
    m, n = A.shape
    if m > n:
        return jnp.zeros((), A.dtype)
    # shared plan validation: int32 rank width is a hard kernel limit
    total = validate_rank_space(m, n, backend="pallas")
    if count is None:
        count = total - q_start
    if q_start + count > total:
        raise ValueError("rank range exceeds C(n, m)")
    table = jnp.asarray(binom_table(n, m, dtype=np.int32))
    padded = max(tile, ((count + tile - 1) // tile) * tile)
    return radic_partial_pallas(A, table, q_start, count, padded,
                                tile=tile, interpret=interpret)


def radic_det_batched_pallas(As: jax.Array, q_start: int = 0,
                             count: int | None = None, *, tile: int = 256,
                             interpret: bool | None = None) -> jax.Array:
    """Batched Radic determinants (or rank-range partials) for a
    shape-uniform stack ``As (B, m, n)`` via the combo-reuse fused kernel
    -> ``(B,)``.  The rank tile is unranked once per grid step and shared
    across the batch; bit-identical to the legacy grid of
    :func:`radic_det_batched_pallas_bygrid`."""
    B, m, n = As.shape
    if m > n:
        return jnp.zeros((B,), As.dtype)
    # shared plan validation: int32 rank width is a hard kernel limit
    total = validate_rank_space(m, n, backend="pallas")
    if count is None:
        count = total - q_start
    if q_start + count > total:
        raise ValueError("rank range exceeds C(n, m)")
    table = jnp.asarray(binom_table(n, m, dtype=np.int32))
    padded = max(tile, ((count + tile - 1) // tile) * tile)
    return radic_batched_partial_pallas(As, table, q_start, count, padded,
                                        tile=tile, interpret=interpret)


def radic_det_batched_grad_pallas(As: jax.Array, cts: jax.Array,
                                  q_start: int = 0, count: int | None = None,
                                  *, tile: int = 256,
                                  interpret: bool | None = None) -> jax.Array:
    """Cofactor-form VJP of :func:`radic_det_batched_pallas`: pull the
    per-matrix cotangents ``cts (B,)`` back through the same rank walk
    -> ``(B, m, n)`` (see DESIGN_GRAD.md)."""
    As = jnp.asarray(As)
    B, m, n = As.shape
    if m > n:
        return jnp.zeros_like(As)
    # shared plan validation: int32 rank width is a hard kernel limit
    total = validate_rank_space(m, n, backend="pallas")
    if count is None:
        count = total - q_start
    if q_start + count > total:
        raise ValueError("rank range exceeds C(n, m)")
    table = jnp.asarray(binom_table(n, m, dtype=np.int32))
    padded = max(tile, ((count + tile - 1) // tile) * tile)
    cts = jnp.reshape(jnp.asarray(cts, As.dtype), (B,))
    return radic_batched_grad_partial_pallas(
        As, cts, table, q_start, count, padded, tile=tile,
        interpret=interpret)


def radic_det_grad_pallas(A: jax.Array, ct, q_start: int = 0,
                          count: int | None = None, *, tile: int = 256,
                          interpret: bool | None = None) -> jax.Array:
    """Scalar-matrix VJP: ``A (m, n)``, scalar ``ct`` -> ``(m, n)``.
    Dispatches the batched grad kernel at B=1 — same guards, same walk."""
    A = jnp.asarray(A)
    m, n = A.shape
    if m > n:
        return jnp.zeros_like(A)
    cts = jnp.reshape(jnp.asarray(ct, A.dtype), (1,))
    return radic_det_batched_grad_pallas(
        A[None], cts, q_start, count, tile=tile, interpret=interpret)[0]


def radic_det_batched_pallas_bygrid(As: jax.Array, q_start: int = 0,
                                    count: int | None = None, *,
                                    tile: int = 256,
                                    interpret: bool | None = None
                                    ) -> jax.Array:
    """Legacy ``(B, num_tiles)``-grid batched dispatch, kept behind the
    same guards as the default path so the parity tests and benchmarks
    can price the combo-reuse kernel against it."""
    B, m, n = As.shape
    if m > n:
        return jnp.zeros((B,), As.dtype)
    # shared plan validation: int32 rank width is a hard kernel limit
    total = validate_rank_space(m, n, backend="pallas")
    if count is None:
        count = total - q_start
    if q_start + count > total:
        raise ValueError("rank range exceeds C(n, m)")
    table = jnp.asarray(binom_table(n, m, dtype=np.int32))
    padded = max(tile, ((count + tile - 1) // tile) * tile)
    return radic_batched_partial_pallas_bygrid(
        As, table, q_start, count, padded, tile=tile, interpret=interpret)
