"""Shared in-kernel routines (pure jnp on loaded VMEM values).

These run inside Pallas kernel bodies *and* inside plain jit (they are
ordinary jnp programs), so the fused kernel and its oracle share one
implementation of the math while the memory movement differs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["batched_det_ge", "unrank_tile", "onehot_selectors",
           "onehot_gather_minors", "radic_signs"]


def batched_det_ge(M: jax.Array) -> jax.Array:
    """Batched determinant via Gaussian elimination w/ partial pivoting.

    ``M (T, m, m) -> (T,)``.  Vectorized across the T lane dimension —
    this replaces the paper's reference [7] PRAM determinant (see
    DESIGN.md §2): TPUs have no per-element processors, so throughput
    comes from lanes, not elimination-depth parallelism.  A zero pivot
    leaves a zero on the diagonal => det 0, the mathematically correct
    answer for a singular minor.
    """
    T, m, m2 = M.shape
    assert m == m2, M.shape
    dtype = M.dtype
    if m == 0:
        return jnp.ones((T,), dtype)
    rows = jax.lax.broadcasted_iota(jnp.int32, (T, m), 1)

    def step(k, carry):
        M, sign = carry
        colsel = (rows == k).astype(dtype)               # (T, m) picks col k
        colM = jnp.einsum("tmn,tn->tm", M, colsel)       # column k of M
        cand = jnp.where(rows >= k, jnp.abs(colM), -1.0)
        piv = jnp.argmax(cand, axis=1).astype(jnp.int32)  # (T,)
        oh_piv = rows == piv[:, None]
        oh_k = rows == k
        sign = sign * jnp.where(piv == k, 1.0, -1.0).astype(dtype)
        row_piv = jnp.einsum("tm,tmn->tn", oh_piv.astype(dtype), M)
        row_k = jnp.einsum("tm,tmn->tn", oh_k.astype(dtype), M)
        M = jnp.where(oh_k[:, :, None], row_piv[:, None, :], M)
        M = jnp.where(oh_piv[:, :, None] & ~oh_k[:, :, None],
                      row_k[:, None, :], M)
        pivval = jnp.sum(row_piv * colsel, axis=1)        # (T,)
        safe = jnp.where(pivval == 0, 1.0, pivval).astype(dtype)
        colM2 = jnp.einsum("tmn,tn->tm", M, colsel)
        factors = jnp.where(rows > k, colM2 / safe[:, None], 0.0)
        M = M - factors[:, :, None] * row_piv[:, None, :]
        return M, sign

    M, sign = jax.lax.fori_loop(0, m - 1, step,
                                (M, jnp.ones((T,), dtype)))
    eye = jnp.eye(m, dtype=dtype)
    diag = jnp.sum(M * eye[None], axis=2)                 # (T, m)
    return sign * jnp.prod(diag, axis=1)


def unrank_tile(qs: jax.Array, n: int, m: int, table: jax.Array
                ) -> jax.Array:
    """Tile-vectorized combinatorial addition: ``(T,) -> (T, m)`` 1-indexed.

    Same walk as :func:`repro.core.unrank.unrank_jnp`; kept separate so the
    kernel body has no dependency on jit-level helpers.
    """
    pos = (qs * 0).astype(jnp.int32)
    combo = jnp.broadcast_to(pos[:, None], (qs.shape[0], m))
    cols = jax.lax.broadcasted_iota(jnp.int32, (qs.shape[0], m), 1)

    def step(s, carry):
        pos, q_rem, combo = carry
        v = s + 1
        colidx = jnp.clip(m - 1 - pos, 0, m)              # (T,)
        # gather C(n-v, m-1-pos) from the table row via one-hot dot
        row = jax.lax.dynamic_slice_in_dim(table, n - v, 1, 0)[0]  # (m+1,)
        sel = jax.lax.broadcasted_iota(jnp.int32, (qs.shape[0], m + 1), 1)
        # dtype pinned to the carry: under x64 an unpinned integer sum
        # promotes int32 -> int64 and breaks the fori_loop carry type
        cnt = jnp.sum(jnp.where(sel == colidx[:, None], row[None, :], 0),
                      axis=1, dtype=q_rem.dtype)
        active = pos < m
        place = active & (q_rem < cnt)
        combo = jnp.where(place[:, None] & (cols == pos[:, None]), v, combo)
        q_rem = jnp.where(active & ~place, q_rem - cnt, q_rem)
        pos = pos + place.astype(jnp.int32)
        return pos, q_rem, combo

    _, _, combo = jax.lax.fori_loop(0, n, step, (pos, qs, combo))
    return combo


def onehot_selectors(combos: jax.Array, n: int, dtype) -> jax.Array:
    """One-hot column selectors: ``combos (T,m) 1-indexed -> (T,m,n)``.

    Split out of :func:`onehot_gather_minors` so the combo-reuse batched
    kernel can build the selectors once per rank tile and contract them
    against every matrix in the batch (the selector depends only on the
    tile, not on A).
    """
    T, m = combos.shape
    jidx = jax.lax.broadcasted_iota(jnp.int32, (T, m, n), 2)
    return (combos[:, :, None] - 1 == jidx).astype(dtype)


def onehot_gather_minors(A: jax.Array, combos: jax.Array) -> jax.Array:
    """Column gather as an MXU matmul: ``A (m,n), combos (T,m) -> (T,m,m)``.

    Builds one-hot selectors and contracts over n, so minors are produced
    by the systolic array instead of scatter/gather (DESIGN.md §2).  The
    result is the *transposed* minor — determinant-invariant.
    """
    oh = onehot_selectors(combos, A.shape[1], A.dtype)
    return jnp.einsum("tkn,an->tka", oh, A,
                      preferred_element_type=A.dtype)


def radic_signs(combos: jax.Array, m: int, dtype=jnp.float32) -> jax.Array:
    """(−1)^(r+s) per lane."""
    r = m * (m + 1) // 2
    parity = (jnp.sum(combos, axis=1) + r) & 1
    return (1 - 2 * parity).astype(dtype)
