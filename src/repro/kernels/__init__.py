"""Pallas TPU kernels for the paper's compute hot-spot (the per-rank
unrank → gather → determinant pipeline), validated in interpret mode on
CPU against the numpy oracles in :mod:`repro.kernels.ref`."""

from . import ops, ref
from .minor_det import minor_det_pallas
from .radic_fused import radic_partial_pallas
from .unrank_kernel import unrank_pallas

__all__ = ["ops", "ref", "minor_det_pallas", "radic_partial_pallas",
           "unrank_pallas"]
