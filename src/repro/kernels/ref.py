"""Pure numpy/itertools oracles for every Pallas kernel in this package.

Each kernel's semantics are *defined* by the function here with the same
name; tests sweep shapes/dtypes and assert allclose against these.
"""

from __future__ import annotations

import numpy as np

from repro.core.oracle import radic_det_oracle  # noqa: F401 (re-export)
from repro.core.pascal import comb
from repro.core.unrank import unrank_py

__all__ = ["unrank_ref", "minor_det_ref", "radic_partial_ref"]


def unrank_ref(qs: np.ndarray, n: int, m: int) -> np.ndarray:
    """Batched unranking oracle: (B,) ranks -> (B, m) 1-indexed combos."""
    return np.array([unrank_py(int(q), n, m) for q in np.asarray(qs)],
                    dtype=np.int32).reshape(len(qs), m)


def minor_det_ref(mats: np.ndarray) -> np.ndarray:
    """Batched determinant oracle: (B, m, m) -> (B,) float."""
    return np.linalg.det(np.asarray(mats, dtype=np.float64)).astype(
        np.asarray(mats).dtype)


def radic_partial_ref(A: np.ndarray, q_start: int, count: int) -> float:
    """Signed minor sum over ranks [q_start, q_start + count) — float64."""
    A = np.asarray(A, dtype=np.float64)
    m, n = A.shape
    assert q_start + count <= comb(n, m)
    r = m * (m + 1) // 2
    total = 0.0
    for q in range(q_start, q_start + count):
        combo = unrank_py(q, n, m)
        s = sum(combo)
        sign = -1.0 if (r + s) % 2 else 1.0
        cols = [c - 1 for c in combo]
        total += sign * np.linalg.det(A[:, cols])
    return total
