"""Batched small-matrix determinant Pallas kernel.

Grid over batch tiles; each grid step loads a ``(TILE, m, m)`` block into
VMEM and runs lane-vectorized pivoted Gaussian elimination
(:func:`repro.kernels.common.batched_det_ge`).  ``m`` is small by the
problem's nature (minors of an m×n matrix), so the whole tile fits VMEM:
``TILE·m²·4B`` ≈ 128·32²·4 = 512 KiB at the extreme end.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import batched_det_ge

__all__ = ["minor_det_kernel", "minor_det_pallas"]


def minor_det_kernel(m_ref, out_ref):
    out_ref[...] = batched_det_ge(m_ref[...])


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def minor_det_pallas(mats: jax.Array, *, tile: int = 128,
                     interpret: bool | None = None) -> jax.Array:
    """``mats (B, m, m) -> (B,)`` determinants.  Pads B to a tile multiple
    with identity matrices (det 1) and slices the pad away."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, m, _ = mats.shape
    dtype = mats.dtype
    pad = (-B) % tile
    if pad:
        eye = jnp.broadcast_to(jnp.eye(m, dtype=dtype), (pad, m, m))
        mats = jnp.concatenate([mats, eye], axis=0)
    Bp = mats.shape[0]
    out = pl.pallas_call(
        minor_det_kernel,
        grid=(Bp // tile,),
        in_specs=[pl.BlockSpec((tile, m, m), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Bp,), dtype),
        interpret=interpret,
    )(mats)
    return out[:B]
