"""Plan/execute engine unifying every Radic determinant evaluation path.

The paper's rank space C(n, m) factors into independent work units, and
every execution strategy in this repo — jnp flat streaming, jnp batched,
the fused Pallas kernel, mesh-distributed grains/flat — schedules those
same units differently.  Before this module each strategy carried its
own guards, Pascal-table binding and dispatch plumbing; the engine
factors the shared per-shape state into one immutable compilation
artifact (:class:`DetPlan`) and one router (:class:`DetEngine`) that
plans once and executes many (the planned-pipeline shape of Wei & Chen
2020, with the strategies swappable behind one interface per
Boix-Adserà et al. 2019).

A plan is keyed by everything that selects a distinct device program:
``(m, n, capacity, dtype, backend, mesh, …, x64)``.  Planning performs
*all* validation — ``m > n`` degeneracy, the ``C(n, m)`` integer-width
guards — **before** any backend dispatch, so no backend can be entered
with an overflowing rank space (the structural fix for the historical
``radic_det(backend="pallas")`` ordering bug).  The executable cache is
LRU-bounded (``max_plans``) for long-tail shape traffic: evicted shapes
simply re-plan, and because a plan binds exactly the statics the
pre-engine paths bound, a re-planned shape reproduces bit-identical
results (``tests/test_engine.py``).

Routing table (see DESIGN_ENGINE.md):

====================  ==========================================
plan configuration    executable
====================  ==========================================
``m > n``             jitted zeros (device program, any backend)
jnp, scalar           ``_radic_det_flat`` closure (traced jit)
jnp, batched, cap=C   the same program, AOT-lowered at (C, m, n)
jnp, batched, cap=∅   ``_radic_det_batched_flat`` closure
pallas                ``kernels.ops.radic_det[_batched]_pallas``
mesh                  ``core.distributed`` maker (via compat.py)
====================  ==========================================

All shard_map use stays inside :mod:`repro.core.distributed` and hence
:mod:`repro.parallel.compat`; the engine never touches collectives.
"""

from __future__ import annotations

import functools
import hashlib
import os
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Literal, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel import compat as _compat

from .pascal import INT32_MAX, binom_table, comb
from .radic import (_radic_det_batched_flat, _radic_det_batched_flat_donated,
                    _radic_det_batched_grad_flat, _radic_det_flat,
                    _radic_det_grad_flat)


def _donation_supported() -> bool:
    """Whether the active backend honors ``donate_argnums`` (TPU/GPU).
    CPU compiles donated programs fine but ignores the hint with a
    warning per lowering — so the engine only requests donation where
    it buys something.  Split out for tests to force the donated path."""
    return jax.default_backend() not in ("cpu",)

__all__ = ["DetPlan", "DetEngine", "PlanKey", "default_engine",
           "set_default_engine", "stable_key_hash", "validate_rank_space",
           "rank_table", "plan_statics"]

Backend = Literal["jnp", "pallas"]


# --------------------------------------------------------- shared validation
def validate_rank_space(m: int, n: int, *, backend: str = "jnp",
                        mesh_grains: bool = False) -> int:
    """Validate that C(n, m) fits the target backend's rank-integer width
    and return it.  This runs at *plan* time, before any backend dispatch
    — no path may enter a kernel with an overflowing rank space.

    * ``pallas`` — the TPU kernel casts ranks and table to int32
      regardless of x64, so ``C(n, m) < 2**31`` is a hard requirement.
    * ``jnp`` — int32 ranks unless x64 is enabled (then int64).
    * ``mesh_grains`` — grain starts are unranked on the host with exact
      bigints; no width limit at all.
    """
    total = comb(n, m)
    if mesh_grains or m > n:
        return total
    if backend == "pallas":
        if total > INT32_MAX:
            raise OverflowError(
                f"C({n},{m}) = {total} exceeds int32 (the Pallas kernel "
                "computes ranks in int32 regardless of x64); use the "
                "distributed grain mode.")
    else:
        if total > INT32_MAX and not jax.config.jax_enable_x64:
            raise OverflowError(
                f"C({n},{m}) = {total} exceeds int32; enable x64 or use "
                "repro.core.distributed (mode='grains').")
    return total


def rank_table(n: int, m: int, *, backend: str = "jnp") -> jax.Array:
    """The Pascal table at the rank dtype the backend computes in.

    Always a *concrete* array: plans (and their tables) are LRU-cached
    and outlive any caller's trace, so materializing the table while
    tracing under an outer ``jax.jit`` would leak that trace's constant
    tracer into every later use of the cached plan."""
    if backend == "pallas":
        tdtype = np.int32
    else:
        tdtype = np.int64 if jax.config.jax_enable_x64 else np.int32
    with jax.ensure_compile_time_eval():
        return jnp.asarray(binom_table(n, m, dtype=tdtype))


def plan_statics(m: int, n: int, chunk: int, *, backend: str = "jnp"):
    """``(total, table, clamped chunk)`` — the per-shape state every flat
    jnp program binds.  One place, so traced / AOT / engine paths binding
    it are bit-identical by construction."""
    total = validate_rank_space(m, n, backend=backend)
    table = rank_table(n, m, backend=backend)
    return total, table, int(min(chunk, max(total, 1)))


# ------------------------------------------------------------------ plan key
class PlanKey(NamedTuple):
    """Everything that selects a distinct device program.

    A real tuple (``NamedTuple``), so a mesh-free key is *stable and
    serializable*: it pickles across process boundaries, hashes by
    value and round-trips through ``tuple(key)`` — the properties the
    multi-worker serving front relies on to route by plan family.  The
    routing projection itself ``(m, n, capacity, dtype, x64)`` lives in
    :func:`repro.launch.det_front.route_key`, NOT here: a family's
    capacity component is the *policy bound*, while this key's
    ``capacity`` is one batch's exact size — per-batch keys of one
    family must all land on the same worker, so deriving a routing key
    from an individual plan key would split families across the pool.
    """

    m: int
    n: int
    batched: bool
    capacity: int | None        # None → shape-polymorphic traced program
    dtype: str
    backend: str
    chunk: int                  # as requested (clamp is derived state)
    kahan: bool
    mesh: Any                   # jax.sharding.Mesh (hashable) or None
    axis_names: tuple | None
    batch_axis: str | None
    mode: str                   # mesh scalar only: "grains" | "flat"
    grains_per_device: int
    x64: bool                   # captured at plan time; flips re-plan


def _canonical_key_item(v):
    """Numpy scalars repr differently from the python values they equal
    (``np.int64(3)`` vs ``3`` under numpy >= 2), so a key built from an
    array's ``.shape`` member or decoded off the wire must hash like
    the plain-python key the ring was populated with."""
    if isinstance(v, np.bool_):
        return bool(v)
    if isinstance(v, np.integer):
        return int(v)
    if isinstance(v, np.floating):
        return float(v)
    if isinstance(v, np.str_):
        return str(v)
    if isinstance(v, tuple):
        return tuple(_canonical_key_item(x) for x in v)
    return v


def stable_key_hash(key) -> int:
    """Deterministic 64-bit hash of a (routing) key tuple.

    Builtin ``hash()`` is salted per process for strings
    (``PYTHONHASHSEED``), so it cannot place keys on a consistent-hash
    ring that must agree across processes and restarts.  This hash is a
    pure function of the key's ``repr`` — stable everywhere, invariant
    under numpy-scalar vs python-scalar components and therefore under
    a wire encode/decode round-trip — which is what makes the front's
    re-routing after a worker death deterministic.
    """
    data = repr(tuple(_canonical_key_item(v) for v in key)).encode("utf-8")
    return int.from_bytes(hashlib.blake2b(data, digest_size=8).digest(),
                          "big")


def _make_differentiable(primal: Callable, grad_fn: Callable) -> Callable:
    """Wrap a plan's *traced* primal closure in a ``jax.custom_vjp`` whose
    backward pass is the plan's cofactor-form grad program.

    Eager forward calls execute ``primal`` directly (jax's eval trace
    calls the wrapped function without tracing it), so the value path is
    unchanged; only under differentiation do ``fwd``/``bwd`` trace.  The
    primal passed here must be the traced-closure program, never an
    AOT-compiled executable — compiled executables reject tracers, and
    ``jax.jit(jax.grad(...))`` traces the bwd rule too, which is why the
    AOT grad executable lives separately on ``DetPlan.grad_executable``
    for the serving tier's concrete-batch dispatch.
    """

    @jax.custom_vjp
    def det_fn(A):
        return primal(A)

    def det_fwd(A):
        return primal(A), A

    def det_bwd(A, ct):
        return (grad_fn(A, ct),)

    det_fn.defvjp(det_fwd, det_bwd)
    return det_fn


def _zeros_grad(A: jax.Array, ct: jax.Array) -> jax.Array:
    """m > n ⇒ det ≡ 0 ⇒ the pullback is identically zero."""
    del ct
    return jnp.zeros_like(jnp.asarray(A))


# jitted degenerate programs: m > n ⇒ det = 0 by the paper's definition,
# but normalized as a *device* program so every configuration (backend,
# mesh or not) hands back a committed jax.Array like the real paths do.
@jax.jit
def _zeros_scalar(A: jax.Array) -> jax.Array:
    return jnp.zeros((), A.dtype)


@jax.jit
def _zeros_batched(As: jax.Array) -> jax.Array:
    return jnp.zeros((As.shape[0],), As.dtype)


@dataclass(frozen=True, eq=False)
class DetPlan:
    """Immutable per-shape compilation artifact: validated statics plus
    the executable.  Calling the plan runs the executable; everything
    host-side (validation, table build, grain unranking, AOT lowering)
    happened at plan time.  ``eq=False``: plans compare by identity —
    the generated value-eq would hit the device ``table`` array (ambiguous
    truth value / unhashable); the engine cache already guarantees one
    plan per key."""

    key: PlanKey
    total: int                  # C(n, m)
    chunk: int                  # clamped to the rank space
    degenerate: bool            # m > n: executable is the zeros program
    lowered: bool               # True when AOT-lowered at a capacity
    table: Any = field(repr=False)          # device Pascal table or None
    executable: Callable = field(repr=False)
    # Second plan-time artifact (DESIGN_GRAD.md): the cofactor-form VJP
    # over the same rank walk.  ``grad_executable(A, ct) -> ∂/∂A`` is the
    # serving-grade program (AOT-lowered at capacity where the forward
    # is); ``differentiable`` is the custom_vjp-wrapped traced closure
    # behind ``jax.grad(radic_det)`` / ``jax.grad(radic_det_batched)``.
    grad_executable: Callable = field(repr=False)
    differentiable: Callable = field(repr=False)

    @property
    def m(self) -> int:
        return self.key.m

    @property
    def n(self) -> int:
        return self.key.n

    @property
    def capacity(self) -> int | None:
        return self.key.capacity

    @property
    def backend(self) -> str:
        return self.key.backend

    def __call__(self, A: jax.Array) -> jax.Array:
        return self.executable(A)

    def grad(self, A: jax.Array, ct) -> jax.Array:
        """Pull the cotangent(s) back through the determinant: scalar
        plans take ``A (m, n)`` and a scalar ``ct``; batched plans take
        ``As (B, m, n)`` and ``cts (B,)`` and return ``(B, m, n)``."""
        return self.grad_executable(A, ct)


# -------------------------------------------------------------- the engine
class DetEngine:
    """Plan once, execute many — with an LRU-bounded executable cache.

    The cache bound exists for long-tail shape traffic (the serving
    tier's open problem): an unbounded per-(shape, capacity) executable
    map grows without limit under adversarial or merely diverse request
    streams.  Eviction is safe because plans are pure functions of their
    key — an evicted shape re-plans and reproduces bit-identical results.

    Thread-safe: lookups and inserts are locked; compilation happens
    outside the lock, and a racing duplicate build keeps the first
    inserted plan so every caller converges on one executable.
    """

    # reprolint lock-discipline registry (see DESIGN_LINT.md): the LRU
    # map and its counters are shared by every dispatcher thread.
    _GUARDED_BY = {
        "_plans": ("_lock",),
        "_hits": ("_lock",),
        "_misses": ("_lock",),
        "_evictions": ("_lock",),
        "_store_hits": ("_lock",),
        "_store_misses": ("_lock",),
    }

    def __init__(self, max_plans: int = 128,
                 persist_dir: str | None = None):
        if max_plans < 1:
            raise ValueError("max_plans must be >= 1")
        self.max_plans = max_plans
        self._plans: OrderedDict[PlanKey, DetPlan] = OrderedDict()
        self._lock = threading.Lock()
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._store_hits = 0
        self._store_misses = 0
        # Optional durable plan store (DESIGN_PERSIST.md): consulted on
        # cache misses, written back asynchronously after fresh builds.
        self.store = None
        if persist_dir is not None:
            from repro.checkpoint.plan_store import PlanStore
            self.store = PlanStore(persist_dir, env={
                "jax": jax.__version__,
                "backend": jax.default_backend(),
            })
            # The store dir also houses an XLA persistent compilation
            # cache: on jax legs where blob reload is unsafe (the default
            # — see the compat export seam) this is what makes a warm
            # start skip the XLA compile, not just the store lookup.
            _compat.enable_compilation_cache(
                os.path.join(persist_dir, "xla-cache"))

    # ------------------------------------------------------------- planning
    def plan(self, m: int, n: int, *, batched: bool = True,
             capacity: int | None = None, dtype=np.float32,
             chunk: int = 2048, backend: Backend = "jnp",
             kahan: bool = False, mesh=None,
             axis_names: Sequence[str] | None = None,
             batch_axis: str | None = None,
             mode: Literal["grains", "flat"] = "grains",
             grains_per_device: int = 1) -> DetPlan:
        """Return the cached plan for this configuration, building it if
        absent.  All validation happens here, before backend dispatch."""
        if backend not in ("jnp", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        if kahan and batched:
            raise ValueError("kahan compensation is flat-mode only")
        if capacity is not None and not batched:
            raise ValueError("capacity is a batched-plan parameter")
        key = PlanKey(
            m=int(m), n=int(n), batched=batched,
            capacity=None if capacity is None else int(capacity),
            dtype=np.dtype(dtype).name, backend=backend, chunk=int(chunk),
            kahan=kahan, mesh=mesh,
            axis_names=None if axis_names is None else tuple(axis_names),
            batch_axis=batch_axis, mode=mode,
            grains_per_device=int(grains_per_device),
            x64=bool(jax.config.jax_enable_x64))
        with self._lock:
            plan = self._plans.get(key)
            if plan is not None:
                self._plans.move_to_end(key)
                self._hits += 1
                return plan
        built = None
        consulted = self.store is not None and self._persistable(key)
        if consulted:
            built = self._restore_from_store(key)
            with self._lock:
                if built is not None:
                    self._store_hits += 1
                else:
                    self._store_misses += 1
        if built is None:
            built = self._build(key)
            if consulted:
                self._persist_async(key, built)
        with self._lock:
            plan = self._plans.get(key)
            if plan is not None:  # racing build: first insert wins
                self._plans.move_to_end(key)
                self._hits += 1
                return plan
            self._misses += 1
            self._plans[key] = built
            while len(self._plans) > self.max_plans:
                self._plans.popitem(last=False)
                self._evictions += 1
        return built

    # ------------------------------------------------------------ execution
    def det(self, A: jax.Array, *, chunk: int = 2048, kahan: bool = False,
            backend: Backend = "jnp", **mesh_kw) -> jax.Array:
        """Scalar convenience: plan for ``A.shape`` and execute."""
        A = jnp.asarray(A)
        m, n = A.shape
        return self.plan(m, n, batched=False, dtype=A.dtype, chunk=chunk,
                         kahan=kahan, backend=backend, **mesh_kw)(A)

    def det_batched(self, As: jax.Array, *, chunk: int = 2048,
                    backend: Backend = "jnp", **mesh_kw) -> jax.Array:
        """Batched convenience: plan for ``As.shape[1:]`` and execute."""
        As = jnp.asarray(As)
        _, m, n = As.shape
        return self.plan(m, n, batched=True, dtype=As.dtype, chunk=chunk,
                         backend=backend, **mesh_kw)(As)

    # ------------------------------------------------------------- the cache
    def cache_info(self) -> dict:
        with self._lock:
            return {"size": len(self._plans), "max_plans": self.max_plans,
                    "hits": self._hits, "misses": self._misses,
                    "evictions": self._evictions,
                    "store_hits": self._store_hits,
                    "store_misses": self._store_misses}

    def cached_keys(self) -> list[PlanKey]:
        """LRU order, oldest first (introspection/tests)."""
        with self._lock:
            return list(self._plans)

    def clear(self):
        with self._lock:
            self._plans.clear()

    # ------------------------------------------------- persistence (store)
    #
    # The durable plan store (DESIGN_PERSIST.md) is consulted on cache
    # misses and written back to after fresh builds.  A store *hit*
    # means the store held a valid record for this exact key — when the
    # record carries serialized AOT executables (jax.export leg) the
    # compile is skipped entirely; a metadata-only record still re-lowers
    # from statics, which is what prefill needs (pay the compile at join
    # time, not on the first request).  Mesh plans are never persisted:
    # a Mesh is a live device object with no cross-process identity.

    @staticmethod
    def _persistable(key: PlanKey) -> bool:
        return key.mesh is None

    @staticmethod
    def _key_meta(key: PlanKey) -> dict:
        """Mesh-free plain-JSON form of a PlanKey — the store's record
        of *what* was planned, sufficient to re-plan it elsewhere."""
        return {"m": key.m, "n": key.n, "batched": key.batched,
                "capacity": key.capacity, "dtype": key.dtype,
                "backend": key.backend, "chunk": key.chunk,
                "kahan": key.kahan, "mode": key.mode,
                "grains_per_device": key.grains_per_device, "x64": key.x64}

    @staticmethod
    def _plan_kwargs(meta) -> dict | None:
        """Decode a stored/wire key meta back into ``plan()`` kwargs;
        None if malformed or its x64 stamp disagrees with this process
        (x64 flips select different programs — never mix them)."""
        if not isinstance(meta, dict):
            return None
        try:
            if bool(meta.get("x64", False)) != bool(
                    jax.config.jax_enable_x64):
                return None
            cap = meta.get("capacity")
            return dict(
                m=int(meta["m"]), n=int(meta["n"]),
                batched=bool(meta.get("batched", True)),
                capacity=None if cap is None else int(cap),
                dtype=str(meta.get("dtype", "float32")),
                chunk=int(meta.get("chunk", 2048)),
                backend=str(meta.get("backend", "jnp")),
                kahan=bool(meta.get("kahan", False)),
                mode=str(meta.get("mode", "grains")),
                grains_per_device=int(meta.get("grains_per_device", 1)))
        except (KeyError, TypeError, ValueError):
            return None

    def _restore_from_store(self, key: PlanKey) -> DetPlan | None:
        rec = self.store.get(stable_key_hash(key))
        if rec is None:
            return None
        meta, blobs = rec
        if meta.get("key") != self._key_meta(key):
            return None     # hash collision or corrupt entry: miss
        plan = self._plan_from_blobs(key, blobs) if blobs else None
        # metadata-only record (no export on this jax, or a non-AOT
        # plan): still a store hit — re-lower from the cached statics
        return plan if plan is not None else self._build(key)

    def _plan_from_blobs(self, key: PlanKey, blobs: dict) -> DetPlan | None:
        """Rebuild an AOT batched plan from serialized executables.

        Only jnp/batched/capacity plans ever carry blobs (they are the
        only ``lowered=True`` programs).  Any deserialization failure
        degrades to None — caller re-lowers from statics instead.
        """
        if (key.backend != "jnp" or not key.batched
                or key.capacity is None or key.m > key.n):
            return None
        fwd_b, grad_b = blobs.get("fwd"), blobs.get("grad")
        if fwd_b is None or grad_b is None:
            return None
        exe = _compat.deserialize_exported(fwd_b)
        gexe = _compat.deserialize_exported(grad_b)
        if exe is None or gexe is None:
            return None
        total, table, chunk = plan_statics(key.m, key.n, key.chunk)
        execute_traced, grad_traced = self._traced_batched(
            key, table, total, chunk)
        execute = functools.partial(lambda As, _e, _t: _e(As, _t),
                                    _e=exe, _t=table)
        grad_execute = functools.partial(
            lambda As, cts, _e, _t: _e(As, cts, _t), _e=gexe, _t=table)
        return DetPlan(key=key, total=total, chunk=chunk, degenerate=False,
                       lowered=True, table=table, executable=execute,
                       grad_executable=grad_execute,
                       differentiable=_make_differentiable(
                           execute_traced, grad_traced))

    def _persist_async(self, key: PlanKey, plan: DetPlan) -> None:
        """Enqueue a store write-back for a freshly built plan.

        Export serialization is deferred as callables evaluated on the
        store's writer thread — the dispatch path never pays it.
        """
        meta = {"key": self._key_meta(key), "total": plan.total,
                "chunk": plan.chunk, "lowered": plan.lowered,
                "degenerate": plan.degenerate}
        blobs = {}
        if plan.lowered and not plan.degenerate:
            batch_s = jax.ShapeDtypeStruct(
                (key.capacity, key.m, key.n), np.dtype(key.dtype))
            ct_s = jax.ShapeDtypeStruct((key.capacity,),
                                        np.dtype(key.dtype))
            fn = (_radic_det_batched_flat_donated if _donation_supported()
                  else _radic_det_batched_flat)
            blobs = {
                "fwd": functools.partial(
                    _compat.serialize_lowered, fn, batch_s, plan.table,
                    plan.total, plan.chunk),
                "grad": functools.partial(
                    _compat.serialize_lowered, _radic_det_batched_grad_flat,
                    batch_s, ct_s, plan.table, plan.total, plan.chunk),
            }
        self.store.put_async(stable_key_hash(key), meta, blobs)

    def flush_store(self) -> None:
        """Block until pending store write-backs land (tests/shutdown)."""
        if self.store is not None:
            self.store.flush()

    def prefill(self, families=None) -> int:
        """Warm the plan cache — store first, compile second.

        ``families``: iterable of key-meta dicts (e.g. decoded from a
        join handshake's prefill list); with None, every family the
        store holds is planned.  Malformed entries, x64 mismatches and
        plan failures are skipped.  Returns the number of entries
        successfully planned (cache hits included — already warm counts
        as warm).
        """
        if families is None:
            if self.store is None:
                return 0
            families = [m.get("key") for m in self.store.families()]
        warmed = 0
        for meta in families:
            kw = self._plan_kwargs(meta)
            if kw is None:
                continue
            try:
                self.plan(**kw)
                warmed += 1
            except Exception:   # noqa: BLE001 — prefill is best-effort
                continue
        return warmed

    # ------------------------------------------------------------- builders
    def _build(self, key: PlanKey) -> DetPlan:
        m, n = key.m, key.n
        total = validate_rank_space(
            m, n, backend=key.backend,
            mesh_grains=key.mesh is not None and not key.batched
            and key.mode == "grains")
        if m > n:
            exe = _zeros_batched if key.batched else _zeros_scalar
            def execute(A, _exe=exe):
                return _exe(jnp.asarray(A))
            # det ≡ 0: the jitted zeros program is trivially
            # differentiable, so it is its own custom_vjp-free
            # `differentiable` path.
            return DetPlan(key=key, total=total, chunk=0, degenerate=True,
                           lowered=False, table=None, executable=execute,
                           grad_executable=_zeros_grad,
                           differentiable=execute)
        if key.mesh is not None:
            return self._build_mesh(key, total)
        if key.backend == "pallas":
            return self._build_pallas(key, total)
        return self._build_jnp(key, total)

    @staticmethod
    def _traced_batched(key: PlanKey, table, total: int, chunk: int):
        """The shape-checked traced closures every batched jnp plan
        carries (shared by fresh builds and store restores, so a
        restored plan's ``differentiable`` path is the same program)."""
        m, n = key.m, key.n

        def execute_traced(As, _t=table, _total=total, _c=chunk, _m=m, _n=n):
            As = jnp.asarray(As)
            if As.ndim != 3 or As.shape[1:] != (_m, _n):
                raise ValueError(
                    f"expected (B, {_m}, {_n}), got {As.shape}")
            if As.shape[0] == 0:
                return jnp.zeros((0,), As.dtype)
            return _radic_det_batched_flat(As, _t, _total, _c)

        def grad_traced(As, cts, _t=table, _total=total, _c=chunk):
            As = jnp.asarray(As)
            return _radic_det_batched_grad_flat(
                As, jnp.asarray(cts, As.dtype), _t, _total, _c)

        return execute_traced, grad_traced

    def _build_jnp(self, key: PlanKey, total: int) -> DetPlan:
        m, n = key.m, key.n
        _, table, chunk = plan_statics(m, n, key.chunk)
        if not key.batched:
            def execute(A, _t=table, _total=total, _c=chunk, _k=key.kahan):
                return _radic_det_flat(jnp.asarray(A), _t, _total, _c, _k)

            # The backward walk never compensates: d(kahan_sum)/dA equals
            # d(plain_sum)/dA exactly, the compensation terms are
            # arithmetic identities of the forward accumulation order.
            def grad_execute(A, ct, _t=table, _total=total, _c=chunk):
                A = jnp.asarray(A)
                return _radic_det_grad_flat(
                    A, jnp.asarray(ct, A.dtype), _t, _total, _c)
            return DetPlan(key=key, total=total, chunk=chunk,
                           degenerate=False, lowered=False, table=table,
                           executable=execute, grad_executable=grad_execute,
                           differentiable=_make_differentiable(
                               execute, grad_execute))

        execute_traced, grad_traced = self._traced_batched(
            key, table, total, chunk)
        execute, grad_execute, lowered = execute_traced, grad_traced, False
        if key.capacity is not None:
            # AOT-lower the *same* jitted programs the traced path enters
            # — the identical XLA computations, so results are
            # bit-identical — paying the per-dispatch python once here.
            # Where the backend honors it, the staged batch buffer is
            # donated (it is dead after the dispatch): same program,
            # same results, one less live buffer per inflight batch.
            # The grad program does not donate: its (B, m, n) primal
            # input is also its residual, read throughout the walk.
            fn = (_radic_det_batched_flat_donated if _donation_supported()
                  else _radic_det_batched_flat)
            batch_s = jax.ShapeDtypeStruct((key.capacity, m, n),
                                           np.dtype(key.dtype))
            ct_s = jax.ShapeDtypeStruct((key.capacity,), np.dtype(key.dtype))
            try:
                exe = fn.lower(batch_s, table, total, chunk).compile()
                execute = functools.partial(lambda As, _e, _t: _e(As, _t),
                                            _e=exe, _t=table)
                gexe = _radic_det_batched_grad_flat.lower(
                    batch_s, ct_s, table, total, chunk).compile()
                grad_execute = functools.partial(
                    lambda As, cts, _e, _t: _e(As, cts, _t), _e=gexe,
                    _t=table)
                lowered = True
            except Exception:  # noqa: BLE001 — AOT is an optimization only
                execute, grad_execute = execute_traced, grad_traced
        return DetPlan(key=key, total=total, chunk=chunk, degenerate=False,
                       lowered=lowered, table=table, executable=execute,
                       grad_executable=grad_execute,
                       differentiable=_make_differentiable(
                           execute_traced, grad_traced))

    def _build_pallas(self, key: PlanKey, total: int) -> DetPlan:
        from repro.kernels import ops  # lazy: kernels depend on core
        fn = (ops.radic_det_batched_pallas if key.batched
              else ops.radic_det_pallas)
        gfn = (ops.radic_det_batched_grad_pallas if key.batched
               else ops.radic_det_grad_pallas)
        execute = functools.partial(fn, q_start=0, count=total)
        grad_execute = functools.partial(gfn, q_start=0, count=total)
        return DetPlan(key=key, total=total,
                       chunk=int(min(key.chunk, max(total, 1))),
                       degenerate=False, lowered=False, table=None,
                       executable=execute, grad_executable=grad_execute,
                       differentiable=_make_differentiable(
                           execute, grad_execute))

    def _build_mesh(self, key: PlanKey, total: int) -> DetPlan:
        from .distributed import (make_batched_distributed_evaluator,
                                  make_batched_distributed_grad_evaluator,
                                  make_distributed_evaluator)
        if key.batched:
            execute = make_batched_distributed_evaluator(
                key.m, key.n, mesh=key.mesh, axis_names=key.axis_names,
                batch_axis=key.batch_axis, chunk=key.chunk,
                backend=key.backend)
            grad_execute = make_batched_distributed_grad_evaluator(
                key.m, key.n, mesh=key.mesh, axis_names=key.axis_names,
                batch_axis=key.batch_axis, chunk=key.chunk,
                backend=key.backend)
        else:
            execute = make_distributed_evaluator(
                key.m, key.n, mesh=key.mesh, axis_names=key.axis_names,
                grains_per_device=key.grains_per_device, mode=key.mode,
                chunk=key.chunk, backend=key.backend)

            # Scalar mesh plans (grains/flat) serve the interactive
            # single-matrix path; gradient traffic is batched, so the
            # pullback falls back to the single-device flat program.
            # plan_statics re-runs the width guard at first use: a
            # bigint-only grains rank space has no single-device grad.
            def grad_execute(A, ct, _m=key.m, _n=key.n, _chunk=key.chunk):
                total_, table_, chunk_ = plan_statics(_m, _n, _chunk)
                A = jnp.asarray(A)
                return _radic_det_grad_flat(
                    A, jnp.asarray(ct, A.dtype), table_, total_, chunk_)
        return DetPlan(key=key, total=total,
                       chunk=int(min(key.chunk, max(total, 1))),
                       degenerate=False, lowered=False, table=None,
                       executable=execute, grad_executable=grad_execute,
                       differentiable=_make_differentiable(
                           execute, grad_execute))


# ------------------------------------------------------------ default engine
_default_engine: DetEngine | None = None
_default_lock = threading.Lock()


def default_engine() -> DetEngine:
    """The process-wide engine behind the module-level entry points
    (``radic_det``, ``radic_det_batched``, …)."""
    global _default_engine
    with _default_lock:
        if _default_engine is None:
            _default_engine = DetEngine()
        return _default_engine


def set_default_engine(engine: DetEngine | None) -> None:
    """Swap (or with ``None``, reset) the process-wide engine — tests and
    embedders that want their own cache bound."""
    global _default_engine
    with _default_lock:
        _default_engine = engine
