"""Mesh-distributed Radic determinant — the paper's granularity scheme.

Section 5 of the paper: with ``k`` processors, the rank space
``[0, C(n,m))`` is cut into ``k`` contiguous grains; each processor unranks
its grain start once (combinatorial addition) and then walks successors
inside the grain.  Here a "processor" is a mesh device; the tree-sum of the
PRAM CREW analysis becomes a single ``psum`` over the mesh axes.

Two modes:

* ``"grains"`` (default) — grain starts are unranked on the **host with
  exact bigints** (no integer-width limit, works for astronomically large
  ``C(n,m)``); devices enumerate successors lock-step across their local
  grains via a vectorized ``scan``.  This is the faithful port of the
  paper's per-processor loop.
* ``"flat"`` — every rank is unranked independently on-device (the
  maximally-parallel PRAM-CRCW shape).  Requires ``C(n,m) < 2**31`` per
  the int32 note in DESIGN.md; supports the fused Pallas kernel backend.

This module is the engine's mesh backend (DESIGN_ENGINE.md): the
``make_*_evaluator`` makers bind the plan-time half — validation, grain
planning with host-bigint unranking, Pascal table, the ``shard_map``-built
worker — once per shape, and the public ``radic_det*_distributed``
wrappers route through :class:`repro.core.engine.DetEngine` so repeated
same-shape calls reuse the planned worker instead of re-unranking grain
starts every call.  All ``shard_map`` use goes through
:mod:`repro.parallel.compat`.

Straggler mitigation: ``grains_per_device > 1`` oversubscribes grains so a
slow device's tail work can be speculatively re-executed by the runtime
(see ``repro.runtime.stragglers``); the reduction is idempotent because
grain partials are keyed by grain id.
"""

from __future__ import annotations

import functools
import math
from typing import Literal, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.compat import psum_scalar, pvary, shard_map

from .engine import rank_table, validate_rank_space
from .pascal import INT32_MAX
from .radic import signed_minor_sum, signed_minor_sum_batched
from .unrank import successor_jnp, unrank_jnp, unrank_py

__all__ = ["radic_det_distributed", "radic_det_batched_distributed",
           "make_distributed_evaluator", "make_batched_distributed_evaluator",
           "make_batched_distributed_grad_evaluator", "plan_grains"]


def plan_grains(total: int, num_grains: int):
    """Contiguous grain bounds: ``num_grains`` slices covering [0, total)."""
    bounds = [total * g // num_grains for g in range(num_grains + 1)]
    starts = bounds[:-1]
    lengths = [b - a for a, b in zip(bounds[:-1], bounds[1:])]
    return starts, lengths


def _default_mesh() -> Mesh:
    devs = np.array(jax.devices())
    return Mesh(devs.reshape(len(devs)), ("workers",))


# ----------------------------------------------------------- plan-time makers
def make_distributed_evaluator(
    m: int,
    n: int,
    *,
    mesh: Mesh,
    axis_names: Sequence[str] | None = None,
    grains_per_device: int = 1,
    mode: Literal["grains", "flat"] = "grains",
    chunk: int = 1024,
    backend: Literal["jnp", "pallas"] = "jnp",
):
    """Bind the host-side half of a mesh evaluation once for one (m, n).

    Grain planning (including the host-bigint grain-start unranking — the
    expensive part for astronomical C(n, m)), the Pascal table and the
    ``shard_map``-built worker are all constructed here; the returned
    ``evaluate(A: (m, n)) -> scalar`` only enters device code.  ``A`` is
    replicated (it is tiny); the rank space is sharded; the result is a
    replicated scalar.  m > n is normalized by the engine before this
    maker runs.
    """
    axes = tuple(axis_names if axis_names is not None else mesh.axis_names)
    D = math.prod(mesh.shape[a] for a in axes)
    total = validate_rank_space(m, n, backend=backend,
                                mesh_grains=(mode == "grains"))
    if mode == "flat":
        return _make_flat(m, n, mesh, axes, D, total, chunk, backend)
    G = D * grains_per_device
    if total < G:  # degenerate: fewer subsets than grains
        G = D  # keep one grain per device, some empty
    starts_q, lengths = plan_grains(total, G)
    starts = np.array([unrank_py(q, n, m) if l > 0 else [1] * m
                       for q, l in zip(starts_q, lengths)], dtype=np.int32)
    max_len = max(lengths) if lengths else 0
    lengths = np.array(lengths, dtype=np.int64 if max(lengths, default=0)
                       > INT32_MAX else np.int32)

    spec_g = P(axes)
    rep = P()

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(rep, spec_g, spec_g), out_specs=rep)
    def worker(A_rep, starts_loc, len_loc):
        # starts_loc: (F, m) — F local grains, walked in lock-step.
        def body(carry, _):
            combos, step, acc = carry
            valid = step < len_loc
            part = signed_minor_sum(A_rep, combos, valid)
            combos = successor_jnp(combos, n)
            return (combos, step + 1, acc + part), None

        init = (starts_loc, jnp.zeros_like(len_loc),
                pvary(jnp.zeros((), A_rep.dtype), axes))
        (_, _, acc), _ = jax.lax.scan(body, init, None, length=max_len)
        return psum_scalar(acc, axes)

    starts_a = jnp.asarray(starts)
    lengths_a = jnp.asarray(lengths)

    def evaluate(A: jax.Array) -> jax.Array:
        return worker(jnp.asarray(A), starts_a, lengths_a)

    return evaluate


def _make_flat(m, n, mesh, axes, D, total, chunk, backend):
    """PRAM-CRCW shape: every rank unranked on-device, D contiguous shards.

    The caller (``make_distributed_evaluator``) has already run the
    int32/x64 width guards via :func:`validate_rank_space`.
    """
    table = rank_table(n, m)  # int64 under x64, int32 otherwise
    starts_q, lengths = plan_grains(total, D)
    tdtype = table.dtype
    starts_q = jnp.asarray(np.array(starts_q, dtype=tdtype))
    lengths_a = jnp.asarray(np.array(lengths, dtype=tdtype))
    max_len = max(lengths)
    chunk = int(min(chunk, max(max_len, 1)))
    num_chunks = -(-max_len // chunk)

    # check_vma=False: pallas_call outputs don't carry vma metadata yet
    @functools.partial(
        shard_map, mesh=mesh, check_vma=False,
        in_specs=(P(), P(), P(axes), P(axes)), out_specs=P())
    def worker(A_rep, tab, q0, cnt):
        q0 = q0[0]
        cnt = cnt[0]
        if backend == "pallas":
            from repro.kernels import ops
            acc = ops.radic_partial_pallas(A_rep, tab, q0, cnt,
                                           num_chunks * chunk)
        else:
            idx = jnp.arange(chunk, dtype=tab.dtype)

            def body(c, acc):
                qs = q0 + c.astype(tab.dtype) * chunk + idx
                valid = qs < q0 + cnt
                combos = unrank_jnp(jnp.where(valid, qs, 0), n, m, tab)
                return acc + signed_minor_sum(A_rep, combos, valid)

            acc = jax.lax.fori_loop(0, num_chunks, body,
                                    pvary(jnp.zeros((), A_rep.dtype), axes))
        return psum_scalar(acc, axes)

    def evaluate(A: jax.Array) -> jax.Array:
        return worker(jnp.asarray(A), table, starts_q, lengths_a)

    return evaluate


def make_batched_distributed_evaluator(
    m: int,
    n: int,
    *,
    mesh: Mesh,
    axis_names: Sequence[str] | None = None,
    batch_axis: str | None = None,
    chunk: int = 1024,
    backend: Literal["jnp", "pallas"] = "jnp",
):
    """Plan-time half of the batched mesh evaluation for one (m, n).

    Returns ``evaluate(As: (B, m, n)) -> (B,)``.  When ``batch_axis`` is
    given the batch dim is sharded over that mesh axis (``B`` must divide
    its size — checked per call, the only per-call validation left) and
    the rank space over the remaining axes; otherwise the batch is
    replicated and the rank space is cut over every axis.
    """
    axes = tuple(axis_names if axis_names is not None else mesh.axis_names)
    if batch_axis is not None:
        if batch_axis not in axes:
            raise ValueError(f"batch_axis {batch_axis!r} not in {axes}")
        rank_axes = tuple(a for a in axes if a != batch_axis)
    else:
        rank_axes = axes
    total = validate_rank_space(m, n, backend=backend)
    table = rank_table(n, m)  # int64 under x64, int32 otherwise
    D = math.prod(mesh.shape[a] for a in rank_axes)
    starts_q, lengths = plan_grains(total, D)
    tdtype = table.dtype
    starts_q = jnp.asarray(np.array(starts_q, dtype=tdtype))
    lengths_a = jnp.asarray(np.array(lengths, dtype=tdtype))
    max_len = max(lengths)
    chunk = int(min(chunk, max(max_len, 1)))
    num_chunks = -(-max_len // chunk)

    @functools.partial(
        shard_map, mesh=mesh, check_vma=False,
        in_specs=(P(batch_axis), P(), P(rank_axes), P(rank_axes)),
        out_specs=P(batch_axis))
    def worker(As_loc, tab, q0, cnt):
        q0 = q0[0]
        cnt = cnt[0]
        if backend == "pallas":
            from repro.kernels import ops
            acc = ops.radic_batched_partial_pallas(As_loc, tab, q0, cnt,
                                                   num_chunks * chunk)
        else:
            idx = jnp.arange(chunk, dtype=tab.dtype)

            def body(c, acc):
                qs = q0 + c.astype(tab.dtype) * chunk + idx
                valid = qs < q0 + cnt
                combos = unrank_jnp(jnp.where(valid, qs, 0), n, m, tab)
                return acc + signed_minor_sum_batched(As_loc, combos, valid)

            zero = pvary(jnp.zeros((As_loc.shape[0],), As_loc.dtype),
                         rank_axes)
            acc = jax.lax.fori_loop(0, num_chunks, body, zero)
        return psum_scalar(acc, rank_axes)

    def evaluate(As: jax.Array) -> jax.Array:
        As = jnp.asarray(As)
        if As.ndim != 3 or As.shape[1:] != (m, n):
            raise ValueError(f"expected (B, {m}, {n}), got {As.shape}")
        if batch_axis is not None and As.shape[0] % mesh.shape[batch_axis]:
            raise ValueError(
                f"batch {As.shape[0]} is not divisible by mesh axis "
                f"{batch_axis} size {mesh.shape[batch_axis]}")
        return worker(As, table, starts_q, lengths_a)

    return evaluate


def make_batched_distributed_grad_evaluator(
    m: int,
    n: int,
    *,
    mesh: Mesh,
    axis_names: Sequence[str] | None = None,
    batch_axis: str | None = None,
    chunk: int = 1024,
    backend: Literal["jnp", "pallas"] = "jnp",
):
    """Cofactor-form VJP of :func:`make_batched_distributed_evaluator`.

    Returns ``grad(As: (B, m, n), cts: (B,)) -> (B, m, n)``.  Sharding
    mirrors the forward exactly — batch over ``batch_axis``, rank space
    over the remaining axes — and each rank shard pulls the cotangents
    back through its own chunk walk, so the tree-sum of forward partials
    becomes a ``psum`` of per-shard gradient partials over the same rank
    axes (DESIGN_GRAD.md).  All collectives go through
    :mod:`repro.parallel.compat`.
    """
    axes = tuple(axis_names if axis_names is not None else mesh.axis_names)
    if batch_axis is not None:
        if batch_axis not in axes:
            raise ValueError(f"batch_axis {batch_axis!r} not in {axes}")
        rank_axes = tuple(a for a in axes if a != batch_axis)
    else:
        rank_axes = axes
    total = validate_rank_space(m, n, backend=backend)
    table = rank_table(n, m)  # int64 under x64, int32 otherwise
    D = math.prod(mesh.shape[a] for a in rank_axes)
    starts_q, lengths = plan_grains(total, D)
    tdtype = table.dtype
    starts_q = jnp.asarray(np.array(starts_q, dtype=tdtype))
    lengths_a = jnp.asarray(np.array(lengths, dtype=tdtype))
    max_len = max(lengths)
    chunk = int(min(chunk, max(max_len, 1)))
    num_chunks = -(-max_len // chunk)

    @functools.partial(
        shard_map, mesh=mesh, check_vma=False,
        in_specs=(P(batch_axis), P(batch_axis), P(), P(rank_axes),
                  P(rank_axes)),
        out_specs=P(batch_axis))
    def grad_worker(As_loc, cts_loc, tab, q0, cnt):
        q0 = q0[0]
        cnt = cnt[0]
        if backend == "pallas":
            from repro.kernels import radic_fused
            g = radic_fused.radic_batched_grad_partial_pallas(
                As_loc, cts_loc, tab, q0, cnt, num_chunks * chunk)
        else:
            idx = jnp.arange(chunk, dtype=tab.dtype)

            def body(c, g):
                qs = q0 + c.astype(tab.dtype) * chunk + idx
                valid = qs < q0 + cnt
                combos = unrank_jnp(jnp.where(valid, qs, 0), n, m, tab)
                _, pull = jax.vjp(
                    lambda a: signed_minor_sum_batched(a, combos, valid),
                    As_loc)
                (gAs,) = pull(cts_loc)
                return g + gAs

            zero = pvary(jnp.zeros_like(As_loc), rank_axes)
            g = jax.lax.fori_loop(0, num_chunks, body, zero)
        return psum_scalar(g, rank_axes)

    def grad(As: jax.Array, cts) -> jax.Array:
        As = jnp.asarray(As)
        if As.ndim != 3 or As.shape[1:] != (m, n):
            raise ValueError(f"expected (B, {m}, {n}), got {As.shape}")
        if batch_axis is not None and As.shape[0] % mesh.shape[batch_axis]:
            raise ValueError(
                f"batch {As.shape[0]} is not divisible by mesh axis "
                f"{batch_axis} size {mesh.shape[batch_axis]}")
        cts = jnp.reshape(jnp.asarray(cts, As.dtype), (As.shape[0],))
        return grad_worker(As, cts, table, starts_q, lengths_a)

    return grad


# ------------------------------------------------------- engine-routed entry
def radic_det_distributed(
    A: jax.Array,
    *,
    mesh: Mesh | None = None,
    axis_names: Sequence[str] | None = None,
    grains_per_device: int = 1,
    mode: Literal["grains", "flat"] = "grains",
    chunk: int = 1024,
    backend: Literal["jnp", "pallas"] = "jnp",
) -> jax.Array:
    """Radic determinant distributed over a device mesh.

    ``A`` is replicated (it is tiny — m×n); the rank space is sharded.
    Returns a replicated scalar.  Routed through the default
    :class:`~repro.core.engine.DetEngine`, so the host-side grain
    planning is cached per (shape, mesh, mode) and paid once.
    """
    from .engine import default_engine  # lazy: engine routes back here
    A = jnp.asarray(A)
    m, n = A.shape
    mesh = mesh if mesh is not None else _default_mesh()
    return default_engine().plan(
        m, n, batched=False, dtype=A.dtype, chunk=chunk, backend=backend,
        mesh=mesh, axis_names=axis_names, mode=mode,
        grains_per_device=grains_per_device).differentiable(A)


def radic_det_batched_distributed(
    As: jax.Array,
    *,
    mesh: Mesh | None = None,
    axis_names: Sequence[str] | None = None,
    batch_axis: str | None = None,
    chunk: int = 1024,
    backend: Literal["jnp", "pallas"] = "jnp",
) -> jax.Array:
    """Batched Radic determinants sharded rank-space × batch over a mesh.

    ``As (B, m, n)`` — one shared (m, n) shape, so the whole batch walks a
    single rank space with one Pascal table.  Returns ``(B,)``.  Routed
    through the default engine (one planned worker per shape × mesh).
    """
    from .engine import default_engine  # lazy: engine routes back here
    As = jnp.asarray(As)
    B, m, n = As.shape
    mesh = mesh if mesh is not None else _default_mesh()
    return default_engine().plan(
        m, n, batched=True, dtype=As.dtype, chunk=chunk, backend=backend,
        mesh=mesh, axis_names=axis_names, batch_axis=batch_axis
        ).differentiable(As)
