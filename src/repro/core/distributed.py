"""Mesh-distributed Radic determinant — the paper's granularity scheme.

Section 5 of the paper: with ``k`` processors, the rank space
``[0, C(n,m))`` is cut into ``k`` contiguous grains; each processor unranks
its grain start once (combinatorial addition) and then walks successors
inside the grain.  Here a "processor" is a mesh device; the tree-sum of the
PRAM CREW analysis becomes a single ``psum`` over the mesh axes.

Two modes:

* ``"grains"`` (default) — grain starts are unranked on the **host with
  exact bigints** (no integer-width limit, works for astronomically large
  ``C(n,m)``); devices enumerate successors lock-step across their local
  grains via a vectorized ``scan``.  This is the faithful port of the
  paper's per-processor loop.
* ``"flat"`` — every rank is unranked independently on-device (the
  maximally-parallel PRAM-CRCW shape).  Requires ``C(n,m) < 2**31`` per
  the int32 note in DESIGN.md; supports the fused Pallas kernel backend.

Straggler mitigation: ``grains_per_device > 1`` oversubscribes grains so a
slow device's tail work can be speculatively re-executed by the runtime
(see ``repro.runtime.stragglers``); the reduction is idempotent because
grain partials are keyed by grain id.
"""

from __future__ import annotations

import functools
import math
from typing import Literal, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.compat import psum_scalar, pvary, shard_map

from .pascal import INT32_MAX, binom_table, comb
from .radic import signed_minor_sum, signed_minor_sum_batched
from .unrank import successor_jnp, unrank_jnp, unrank_py

__all__ = ["radic_det_distributed", "radic_det_batched_distributed",
           "plan_grains"]


def plan_grains(total: int, num_grains: int):
    """Contiguous grain bounds: ``num_grains`` slices covering [0, total)."""
    bounds = [total * g // num_grains for g in range(num_grains + 1)]
    starts = bounds[:-1]
    lengths = [b - a for a, b in zip(bounds[:-1], bounds[1:])]
    return starts, lengths


def _default_mesh() -> Mesh:
    devs = np.array(jax.devices())
    return Mesh(devs.reshape(len(devs)), ("workers",))


def radic_det_distributed(
    A: jax.Array,
    *,
    mesh: Mesh | None = None,
    axis_names: Sequence[str] | None = None,
    grains_per_device: int = 1,
    mode: Literal["grains", "flat"] = "grains",
    chunk: int = 1024,
    backend: Literal["jnp", "pallas"] = "jnp",
) -> jax.Array:
    """Radic determinant distributed over a device mesh.

    ``A`` is replicated (it is tiny — m×n); the rank space is sharded.
    Returns a replicated scalar.
    """
    A = jnp.asarray(A)
    m, n = A.shape
    if m > n:
        return jnp.zeros((), A.dtype)
    mesh = mesh if mesh is not None else _default_mesh()
    axes = tuple(axis_names if axis_names is not None else mesh.axis_names)
    D = math.prod(mesh.shape[a] for a in axes)
    total = comb(n, m)
    G = D * grains_per_device
    if mode == "flat":
        return _flat(A, mesh, axes, D, total, chunk, backend)
    if total < G:  # degenerate: fewer subsets than grains
        G = D  # keep one grain per device, some empty
    starts_q, lengths = plan_grains(total, G)
    starts = np.array([unrank_py(q, n, m) if l > 0 else [1] * m
                       for q, l in zip(starts_q, lengths)], dtype=np.int32)
    max_len = max(lengths) if lengths else 0
    lengths = np.array(lengths, dtype=np.int64 if max(lengths, default=0)
                       > INT32_MAX else np.int32)

    spec_g = P(axes)
    rep = P()

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(rep, spec_g, spec_g), out_specs=rep)
    def worker(A_rep, starts_loc, len_loc):
        # starts_loc: (F, m) — F local grains, walked in lock-step.
        def body(carry, _):
            combos, step, acc = carry
            valid = step < len_loc
            part = signed_minor_sum(A_rep, combos, valid)
            combos = successor_jnp(combos, n)
            return (combos, step + 1, acc + part), None

        init = (starts_loc, jnp.zeros_like(len_loc),
                pvary(jnp.zeros((), A_rep.dtype), axes))
        (_, _, acc), _ = jax.lax.scan(body, init, None, length=max_len)
        return psum_scalar(acc, axes)

    return worker(A, jnp.asarray(starts), jnp.asarray(lengths))


def _flat(A, mesh, axes, D, total, chunk, backend):
    """PRAM-CRCW shape: every rank unranked on-device, D contiguous shards."""
    m, n = A.shape
    if backend == "pallas" and total > INT32_MAX:
        # regardless of x64: the kernel casts ranks/table to int32 (TPU)
        raise OverflowError("pallas backend needs C(n,m) < 2**31; use grains")
    if total > INT32_MAX and not jax.config.jax_enable_x64:
        raise OverflowError("flat mode needs C(n,m) < 2**31; use grains")
    tdtype = np.int64 if jax.config.jax_enable_x64 else np.int32
    table = jnp.asarray(binom_table(n, m, dtype=tdtype))
    starts_q, lengths = plan_grains(total, D)
    starts_q = jnp.asarray(np.array(starts_q, dtype=tdtype))
    lengths_a = jnp.asarray(np.array(lengths, dtype=tdtype))
    max_len = max(lengths)
    chunk = int(min(chunk, max(max_len, 1)))
    num_chunks = -(-max_len // chunk)

    # check_vma=False: pallas_call outputs don't carry vma metadata yet
    @functools.partial(
        shard_map, mesh=mesh, check_vma=False,
        in_specs=(P(), P(), P(axes), P(axes)), out_specs=P())
    def worker(A_rep, tab, q0, cnt):
        q0 = q0[0]
        cnt = cnt[0]
        if backend == "pallas":
            from repro.kernels import ops
            acc = ops.radic_partial_pallas(A_rep, tab, q0, cnt,
                                           num_chunks * chunk)
        else:
            idx = jnp.arange(chunk, dtype=tab.dtype)

            def body(c, acc):
                qs = q0 + c.astype(tab.dtype) * chunk + idx
                valid = qs < q0 + cnt
                combos = unrank_jnp(jnp.where(valid, qs, 0), n, m, tab)
                return acc + signed_minor_sum(A_rep, combos, valid)

            acc = jax.lax.fori_loop(0, num_chunks, body,
                                    pvary(jnp.zeros((), A_rep.dtype), axes))
        return psum_scalar(acc, axes)

    return worker(A, table, starts_q, lengths_a)


def radic_det_batched_distributed(
    As: jax.Array,
    *,
    mesh: Mesh | None = None,
    axis_names: Sequence[str] | None = None,
    batch_axis: str | None = None,
    chunk: int = 1024,
    backend: Literal["jnp", "pallas"] = "jnp",
) -> jax.Array:
    """Batched Radic determinants sharded rank-space × batch over a mesh.

    ``As (B, m, n)`` — one shared (m, n) shape, so the whole batch walks a
    single rank space with one Pascal table.  When ``batch_axis`` is given
    the batch dim is sharded over that mesh axis (``B`` must divide its
    size) and the rank space over the remaining axes; otherwise the batch
    is replicated and the rank space is cut over every axis, exactly like
    :func:`radic_det_distributed` flat mode.  Returns ``(B,)``.
    """
    As = jnp.asarray(As)
    B, m, n = As.shape
    if m > n:
        return jnp.zeros((B,), As.dtype)
    mesh = mesh if mesh is not None else _default_mesh()
    axes = tuple(axis_names if axis_names is not None else mesh.axis_names)
    if batch_axis is not None:
        if batch_axis not in axes:
            raise ValueError(f"batch_axis {batch_axis!r} not in {axes}")
        if B % mesh.shape[batch_axis]:
            raise ValueError(
                f"batch {B} is not divisible by mesh axis {batch_axis} "
                f"size {mesh.shape[batch_axis]}")
        rank_axes = tuple(a for a in axes if a != batch_axis)
    else:
        rank_axes = axes
    total = comb(n, m)
    if backend == "pallas" and total > INT32_MAX:
        # regardless of x64: the kernel casts ranks/table to int32 (TPU)
        raise OverflowError("pallas backend needs C(n,m) < 2**31; use grains")
    if total > INT32_MAX and not jax.config.jax_enable_x64:
        raise OverflowError("batched mode needs C(n,m) < 2**31; use grains")
    tdtype = np.int64 if jax.config.jax_enable_x64 else np.int32
    table = jnp.asarray(binom_table(n, m, dtype=tdtype))
    D = math.prod(mesh.shape[a] for a in rank_axes)
    starts_q, lengths = plan_grains(total, D)
    starts_q = jnp.asarray(np.array(starts_q, dtype=tdtype))
    lengths_a = jnp.asarray(np.array(lengths, dtype=tdtype))
    max_len = max(lengths)
    chunk = int(min(chunk, max(max_len, 1)))
    num_chunks = -(-max_len // chunk)

    @functools.partial(
        shard_map, mesh=mesh, check_vma=False,
        in_specs=(P(batch_axis), P(), P(rank_axes), P(rank_axes)),
        out_specs=P(batch_axis))
    def worker(As_loc, tab, q0, cnt):
        q0 = q0[0]
        cnt = cnt[0]
        if backend == "pallas":
            from repro.kernels import ops
            acc = ops.radic_batched_partial_pallas(As_loc, tab, q0, cnt,
                                                   num_chunks * chunk)
        else:
            idx = jnp.arange(chunk, dtype=tab.dtype)

            def body(c, acc):
                qs = q0 + c.astype(tab.dtype) * chunk + idx
                valid = qs < q0 + cnt
                combos = unrank_jnp(jnp.where(valid, qs, 0), n, m, tab)
                return acc + signed_minor_sum_batched(As_loc, combos, valid)

            zero = pvary(jnp.zeros((As_loc.shape[0],), As_loc.dtype),
                         rank_axes)
            acc = jax.lax.fori_loop(0, num_chunks, body, zero)
        return psum_scalar(acc, rank_axes)

    return worker(As, table, starts_q, lengths_a)
