"""Independent numpy/itertools oracles for the Radic determinant.

Everything here is deliberately *simple and slow* — pure enumeration with
``itertools.combinations`` (which emits dictionary order by construction)
and ``np.linalg.det`` in float64, plus an exact integer Bareiss path for
small integer matrices.  All production paths (jnp, shard_map, Pallas) are
tested against these.
"""

from __future__ import annotations

import itertools
from fractions import Fraction

import numpy as np

__all__ = [
    "combinations_lex",
    "radic_det_oracle",
    "radic_det_exact",
    "det_exact",
]


def combinations_lex(n: int, m: int) -> list[tuple[int, ...]]:
    """All m-subsets of {1..n} in dictionary order (paper Table 2)."""
    return [tuple(c) for c in itertools.combinations(range(1, n + 1), m)]


def radic_det_oracle(A: np.ndarray) -> float:
    """Radic determinant by brute enumeration, float64."""
    A = np.asarray(A, dtype=np.float64)
    m, n = A.shape
    if m > n:
        return 0.0  # paper Definition 3
    if m == 0:
        return 1.0
    r = m * (m + 1) // 2
    total = 0.0
    for combo in itertools.combinations(range(n), m):
        s = sum(combo) + m  # 1-indexed column sum
        sign = -1.0 if (r + s) % 2 else 1.0
        total += sign * np.linalg.det(A[:, combo])
    return total


def det_exact(M: list[list[Fraction]]) -> Fraction:
    """Exact determinant via fraction-free Bareiss elimination."""
    M = [row[:] for row in M]
    k = len(M)
    if k == 0:
        return Fraction(1)
    sign = Fraction(1)
    prev = Fraction(1)
    for i in range(k - 1):
        if M[i][i] == 0:
            for r in range(i + 1, k):
                if M[r][i] != 0:
                    M[i], M[r] = M[r], M[i]
                    sign = -sign
                    break
            else:
                return Fraction(0)
        for r in range(i + 1, k):
            for c in range(i + 1, k):
                M[r][c] = (M[r][c] * M[i][i] - M[r][i] * M[i][c]) / prev
            M[r][i] = Fraction(0)
        prev = M[i][i]
    return sign * M[k - 1][k - 1]


def radic_det_exact(A) -> Fraction:
    """Exact Radic determinant for (small) rational matrices."""
    rows = [[Fraction(x) for x in row] for row in np.asarray(A).tolist()]
    m = len(rows)
    n = len(rows[0]) if m else 0
    if m > n:
        return Fraction(0)
    r = m * (m + 1) // 2
    total = Fraction(0)
    for combo in itertools.combinations(range(n), m):
        s = sum(combo) + m
        sign = Fraction(-1 if (r + s) % 2 else 1)
        minor = [[rows[a][j] for j in combo] for a in range(m)]
        total += sign * det_exact(minor)
    return total
