"""Literal host-Python port of the paper's Section-4 procedure.

Figure 1's pseudo-code is OCR-garbled (see DESIGN.md §1); this module
implements the procedure *as defined by the prose + Example 1*:

  1. Build Table 1 (``A(j, i) = C(i+j, j)``).
  2. Starting from the First Member and column ``col = n - m``: pick the
     largest row ``j`` whose entry ``C(col + j, j)`` does not exceed ``q``;
     walk left in that row accumulating entries while the running sum stays
     ``<= q`` (``p`` = number of entries consumed);
  3. add ``p`` to place ``m - j`` and cascade the suffix into a consecutive
     run; ``q -= sum``; continue from column ``col - p``; stop at ``q = 0``.

Validated against the paper's own artifacts in tests/test_paper_fidelity.py:
Example 1 (q=49, n=8, m=5 -> [2,5,6,7,8]) and the full Table 2 (all 56
subsets), plus exhaustive equality with the canonical combinatorial-number-
system unranking (:func:`repro.core.unrank.unrank_py`) on small (n, m).
"""

from __future__ import annotations

from typing import Sequence

from .pascal import comb

__all__ = ["combinatorial_addition", "grain_sequence"]


def combinatorial_addition(q: int, n: int, m: int) -> tuple[int, ...]:
    """Add ``q`` to the First Member — the paper's Fig. 1 (first listing)."""
    if not 0 <= q < comb(n, m):
        raise ValueError(f"rank {q} outside [0, C({n},{m}))")
    B = list(range(1, m + 1))  # First Member
    col = n - m                # current (1-indexed) table column
    while q > 0:
        # largest row j with table entry C(col + j, j) <= q
        j = None
        for jj in range(m - 1, -1, -1):
            if comb(col + jj, jj) <= q:
                j = jj
                break
        if j is None:  # cannot happen for valid q (C(col, 0) = 1 <= q)
            raise AssertionError("combinatorial addition stalled")
        # walk left in row j while the running sum stays <= q
        s = 0
        p = 0
        i = col
        while i >= 1 and s + comb(i + j, j) <= q:
            s += comb(i + j, j)
            p += 1
            i -= 1
        # add p to place (m - j), cascade suffix into a consecutive run
        B[m - j - 1] += p
        for h in range(m - j, m):
            B[h] = B[h - 1] + 1
        q -= s
        col -= p
    return tuple(B)


def grain_sequence(start: Sequence[int], count: int, n: int
                   ) -> list[tuple[int, ...]]:
    """The paper's per-processor grain walk (Fig. 1, second listing).

    From ``start``, emit ``count`` consecutive dictionary-order sequences
    (successor chain) — each processor covers ``C(n,m)/k`` of these.
    """
    b = list(start)
    m = len(b)
    out = [tuple(b)]
    for _ in range(count - 1):
        # rightmost place below its cap
        i = m - 1
        while i >= 0 and b[i] >= n - m + i + 1:
            i -= 1
        if i < 0:
            break  # ran past the last member
        b[i] += 1
        for h in range(i + 1, m):
            b[h] = b[h - 1] + 1
        out.append(tuple(b))
    return out
