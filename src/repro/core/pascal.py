"""Pascal-triangle tables (paper Table 1) used by combinatorial addition.

The paper indexes its table as ``A(j, i) = C(i + j, j)`` for rows
``j = 0..m-1`` and columns ``i = 1..n-m`` (the last column holds the place
weights ``C(n-1, m-1), ..., C(n-m, 0)``).  The production code uses the
equivalent canonical table ``T[a, b] = C(a, b)`` because every entry the
walk touches is ``C(n - v, m - 1 - i)`` for some candidate value ``v`` and
position ``i`` — a direct lookup in ``T``.
"""

from __future__ import annotations

import math

import numpy as np

__all__ = [
    "binom_table",
    "paper_table",
    "comb",
    "INT32_MAX",
    "INT64_MAX",
]

INT32_MAX = 2**31 - 1
INT64_MAX = 2**63 - 1


def comb(n: int, k: int) -> int:
    """Exact C(n, k) with Python bigints (0 outside the triangle)."""
    if k < 0 or n < 0 or k > n:
        return 0
    return math.comb(n, k)


def binom_table(n: int, m: int, dtype=np.int64) -> np.ndarray:
    """Canonical table ``T[a, b] = C(a, b)``, shape ``(n+1, m+1)``.

    Entries with ``b > a`` are 0 (used as a natural guard by the
    vectorized unranking walk).  Raises if any entry overflows ``dtype`` —
    callers that need bigger ranges must use the host bigint path
    (:func:`repro.core.unrank.unrank_py`) / the grain mode.
    """
    limit = INT32_MAX if np.dtype(dtype) == np.int32 else INT64_MAX
    # True table peak is the mid column of the last row: C(n, min(m, n//2)).
    # (C(n, m) alone underestimates it when m > n/2 — e.g. (40, 30) stores
    # C(40, 20) ≈ 1.4e11 even though C(40, 30) = C(40, 10) fits int32 —
    # and a wrapping int32 cast would silently corrupt those entries.)
    peak = comb(n, min(m, n // 2))
    if peak > limit:
        raise OverflowError(
            f"binom_table({n},{m}) peak entry C({n},{min(m, n // 2)}) = "
            f"{peak} exceeds {np.dtype(dtype).name}; use the grain mode "
            "(host bigint grain starts + on-device successors)."
        )
    T = np.zeros((n + 1, m + 1), dtype=np.int64)
    T[:, 0] = 1
    for a in range(1, n + 1):
        hi = min(a, m)
        T[a, 1 : hi + 1] = T[a - 1, 0:hi] + T[a - 1, 1 : hi + 1]
    return T.astype(dtype)


def paper_table(n: int, m: int) -> np.ndarray:
    """Literal Table 1 of the paper: ``A[j, i-1] = C(i + j, j)``.

    Shape ``(m, n - m)`` — rows ``j = 0..m-1``, columns ``i = 1..n-m``.
    Kept for fidelity tests; production uses :func:`binom_table`.
    """
    if n <= m:
        return np.zeros((m, 0), dtype=np.int64)
    A = np.zeros((m, n - m), dtype=np.int64)
    for j in range(m):
        for i in range(1, n - m + 1):
            A[j, i - 1] = comb(i + j, j)
    return A
