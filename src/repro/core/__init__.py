"""Core of the paper's contribution: rank-addressable (combinatorial-
addition) enumeration of column subsets and the Radic determinant built on
it, with mesh distribution per the paper's granularity scheme."""

from .pascal import binom_table, comb, paper_table
from .unrank import (first_member, last_member, rank_jnp, rank_py,
                     successor_jnp, successor_py, unrank_jnp, unrank_py)
from .paper_reference import combinatorial_addition, grain_sequence
from .radic import (aot_compile_batched, make_batched_evaluator, radic_det,
                    radic_det_batched, radic_sign, signed_minor_sum,
                    signed_minor_sum_batched)
from .engine import (DetEngine, DetPlan, PlanKey, default_engine,
                     plan_statics, rank_table, set_default_engine,
                     stable_key_hash, validate_rank_space)
from .distributed import (make_batched_distributed_evaluator,
                          make_distributed_evaluator, plan_grains,
                          radic_det_batched_distributed,
                          radic_det_distributed)
from .oracle import (combinations_lex, radic_det_exact, radic_det_oracle)

__all__ = [
    "binom_table", "comb", "paper_table",
    "first_member", "last_member", "rank_jnp", "rank_py",
    "successor_jnp", "successor_py", "unrank_jnp", "unrank_py",
    "combinatorial_addition", "grain_sequence",
    "aot_compile_batched", "make_batched_evaluator", "radic_det",
    "radic_det_batched",
    "radic_sign", "signed_minor_sum", "signed_minor_sum_batched",
    "DetEngine", "DetPlan", "PlanKey", "default_engine",
    "set_default_engine", "plan_statics", "rank_table",
    "stable_key_hash", "validate_rank_space",
    "plan_grains", "radic_det_distributed", "radic_det_batched_distributed",
    "make_distributed_evaluator", "make_batched_distributed_evaluator",
    "combinations_lex", "radic_det_exact", "radic_det_oracle",
]
