"""Combinatorial addition — rank-addressable enumeration of ascending sequences.

This is the paper's core contribution (Section 4, Theorem 2): map an
arbitrary rank ``q`` in ``[0, C(n, m))`` to the ``q``-th ``m``-subset of
``{1..n}`` in dictionary (lexicographic) order, independently of all other
ranks, in ``O(m (n-m))`` time.

Three implementations, all proven equal in tests:

* :func:`unrank_py` / :func:`rank_py` / :func:`successor_py` — exact host
  Python (bigints, no width limit).  Used for grain starts in the
  distributed mode and as the oracle.
* :func:`unrank_jnp` — batched, fully vectorized JAX version.  The walk is
  *lane-uniform in the candidate value* ``v``: one ``fori_loop`` of exactly
  ``n`` steps, per-lane state is only (position ``i``, remaining ``q``).
  This is the TPU-native shape of the paper's PRAM per-processor loop.
* the Pallas kernel (:mod:`repro.kernels.unrank_kernel`) — same walk, run
  on rank *tiles* inside VMEM.

Conventions: combinations are **1-indexed** ascending tuples, matching the
paper (``B_0 = [1, 2, .., m]``).
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .pascal import binom_table, comb

__all__ = [
    "first_member",
    "last_member",
    "unrank_py",
    "rank_py",
    "successor_py",
    "unrank_jnp",
    "rank_jnp",
    "successor_jnp",
]


# --------------------------------------------------------------------------
# Host (exact, bigint) reference path — also the grain-start generator.
# --------------------------------------------------------------------------

def first_member(m: int) -> tuple[int, ...]:
    return tuple(range(1, m + 1))


def last_member(n: int, m: int) -> tuple[int, ...]:
    return tuple(range(n - m + 1, n + 1))


def unrank_py(q: int, n: int, m: int) -> tuple[int, ...]:
    """Exact unranking with Python ints (no overflow)."""
    if not 0 <= q < comb(n, m):
        raise ValueError(f"rank {q} outside [0, C({n},{m}))")
    out = []
    v = 1
    for i in range(m):  # position i gets the smallest feasible value
        while True:
            cnt = comb(n - v, m - 1 - i)
            if q < cnt:
                out.append(v)
                v += 1
                break
            q -= cnt
            v += 1
    return tuple(out)


def rank_py(combo: Sequence[int], n: int, m: int) -> int:
    """Inverse of :func:`unrank_py` (dictionary-order rank, exact)."""
    combo = tuple(combo)
    if len(combo) != m or any(c < 1 or c > n for c in combo):
        raise ValueError(f"not an m-subset of 1..{n}: {combo}")
    if any(a >= b for a, b in zip(combo, combo[1:])):
        raise ValueError(f"not ascending: {combo}")
    q = 0
    prev = 0
    for i, c in enumerate(combo):
        # hockey-stick: sum_{v=prev+1}^{c-1} C(n-v, m-1-i)
        q += comb(n - prev, m - i) - comb(n - c + 1, m - i)
        prev = c
    return q


def successor_py(combo: Sequence[int], n: int) -> tuple[int, ...] | None:
    """Next combination in dictionary order (None past the last member).

    This is the paper's per-grain enumeration step (second listing of
    Fig. 1): find the rightmost place below its cap, bump it, reset the
    suffix to a consecutive run.
    """
    b = list(combo)
    m = len(b)
    for i in range(m - 1, -1, -1):
        if b[i] < n - m + i + 1:
            b[i] += 1
            for j in range(i + 1, m):
                b[j] = b[j - 1] + 1
            return tuple(b)
    return None


# --------------------------------------------------------------------------
# Vectorized JAX path.
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n", "m"))
def unrank_jnp(qs: jax.Array, n: int, m: int, table: jax.Array | None = None
               ) -> jax.Array:
    """Batched unranking: ``qs (B,) int -> combos (B, m) int32`` (1-indexed).

    Vectorized form of the paper's combinatorial-addition walk.  The
    candidate value ``v`` advances ``1..n`` uniformly across lanes (one
    ``fori_loop`` of ``n`` steps); each lane keeps only its current
    position ``i`` and remaining rank.  Lane ``b`` places ``v`` at
    position ``i_b`` iff ``q_b < C(n - v, m - 1 - i_b)``.

    ``table`` lets callers pass a precomputed :func:`binom_table` (required
    inside traced code where ``n, m`` are static anyway).
    """
    if table is None:
        # convenience path (guarded callers pass a table): binom_table's
        # internal peak check bounds this build; importing the engine's
        # validate_rank_space here would cycle engine -> radic -> unrank
        dt = np.int64 if jax.config.jax_enable_x64 else np.int32
        table = jnp.asarray(binom_table(n, m, dtype=dt))  # reprolint: disable=overflow-guard
    qs = jnp.asarray(qs)
    # derive loop state from qs so shard_map varying-axis types propagate
    pos0 = (qs * 0).astype(jnp.int32)
    combo0 = jnp.broadcast_to(pos0[:, None], (qs.shape[0], m))
    cols = jnp.arange(m, dtype=jnp.int32)

    def step(s, carry):
        pos, q_rem, combo = carry
        v = s + 1  # candidate value, uniform across lanes
        row = table[n - v]  # (m+1,) counts C(n-v, *)
        col = jnp.clip(m - 1 - pos, 0, m)
        cnt = jnp.take(row, col)
        active = pos < m
        place = active & (q_rem < cnt)
        combo = jnp.where(place[:, None] & (cols[None, :] == pos[:, None]),
                          v, combo)
        q_rem = jnp.where(active & ~place, q_rem - cnt, q_rem)
        pos = pos + place.astype(jnp.int32)
        return pos, q_rem, combo

    _, _, combo = jax.lax.fori_loop(0, n, step, (pos0, qs, combo0))
    return combo


@functools.partial(jax.jit, static_argnames=("n", "m"))
def rank_jnp(combos: jax.Array, n: int, m: int,
             table: jax.Array | None = None) -> jax.Array:
    """Batched rank: ``combos (B, m) -> (B,)`` (dtype follows the table)."""
    if table is None:
        # convenience path: same justification as unrank_jnp above
        dt = np.int64 if jax.config.jax_enable_x64 else np.int32
        table = jnp.asarray(binom_table(n, m, dtype=dt))  # reprolint: disable=overflow-guard
    prevs = jnp.concatenate(
        [jnp.zeros_like(combos[:, :1]), combos[:, :-1]], axis=1)
    ks = m - jnp.arange(m, dtype=combos.dtype)  # m-i for i=0..m-1
    # contribution_i = C(n - prev_i, m - i) - C(n - c_i + 1, m - i)
    t_hi = table[(n - prevs), ks[None, :]]
    t_lo = table[(n - combos + 1), ks[None, :]]
    return jnp.sum(t_hi - t_lo, axis=1)


@functools.partial(jax.jit, static_argnames=("n",))
def successor_jnp(combos: jax.Array, n: int) -> jax.Array:
    """Batched dictionary-order successor, fully vectorized (no loop).

    ``combos (B, m) -> (B, m)``.  The last member maps to itself (callers
    mask by grain length).
    """
    B, m = combos.shape
    idx = jnp.arange(m, dtype=combos.dtype)
    caps = n - m + idx + 1  # max value allowed at each place
    can = combos < caps[None, :]
    any_can = jnp.any(can, axis=1)
    # last True index per lane
    i_star = (m - 1) - jnp.argmax(can[:, ::-1].astype(jnp.int32), axis=1)
    base = jnp.take_along_axis(combos, i_star[:, None], axis=1)  # (B, 1)
    bumped = base + 1 + (idx[None, :] - i_star[:, None])
    nxt = jnp.where(idx[None, :] < i_star[:, None], combos, bumped)
    return jnp.where(any_can[:, None], nxt, combos).astype(combos.dtype)
