"""Radic determinant of an m×n matrix (paper Definition 3) — JAX path.

``det(A) = Σ_q (−1)^(r + s_q) · det(A[:, B_q])`` over all ``C(n, m)``
column subsets ``B_q`` in dictionary order, where ``r = m(m+1)/2`` and
``s_q`` is the (1-indexed) column sum of ``B_q``.

The flat mode streams the rank space in fixed-size chunks: each chunk is
unranked independently (the paper's contribution — no dependency between
minors), gathered, evaluated and accumulated.  Signs, masking and the
optional Kahan compensation live here; the per-chunk math is shared with
the Pallas kernel's oracle.
"""

from __future__ import annotations

import functools
import math
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from .unrank import unrank_jnp

__all__ = ["radic_det", "radic_det_batched", "make_batched_evaluator",
           "aot_compile_batched", "signed_minor_sum",
           "signed_minor_sum_batched", "radic_sign"]


def radic_sign(combos: jax.Array, m: int) -> jax.Array:
    """(−1)^(r+s) for a batch of 1-indexed combinations ``(B, m)``."""
    r = m * (m + 1) // 2
    parity = (jnp.sum(combos, axis=1) + r) & 1
    return (1 - 2 * parity).astype(jnp.float32)


def signed_minor_sum(A: jax.Array, combos: jax.Array,
                     valid: jax.Array | None = None) -> jax.Array:
    """Σ sign(B_q)·det(A[:, B_q]) for a batch of combinations.

    ``A (m, n)``, ``combos (B, m)`` 1-indexed.  Uses the transposed-minor
    trick: ``det(A[:, J]) == det(A.T[J, :])`` so the gather is a single
    row-take.  Pure jnp — this is also the oracle body for the fused
    Pallas kernel.
    """
    m = A.shape[0]
    minors = jnp.take(A.T, combos - 1, axis=0)  # (B, m, m) transposed minors
    dets = jnp.linalg.det(minors)
    signs = radic_sign(combos, m).astype(dets.dtype)
    terms = signs * dets
    if valid is not None:
        terms = jnp.where(valid, terms, 0)
    return jnp.sum(terms)


def signed_minor_sum_batched(As: jax.Array, combos: jax.Array,
                             valid: jax.Array | None = None) -> jax.Array:
    """Batched-matrix form of :func:`signed_minor_sum`.

    ``As (B, m, n)``, ``combos (C, m)`` 1-indexed — the *same* rank chunk
    is applied to every matrix in the batch (one shared unranking, one
    shared sign vector), which is what makes the batched dispatch cheaper
    than B independent calls.  Returns per-matrix partials ``(B,)``.
    """
    m = As.shape[1]
    # (B, n, m) transposed, then one shared row-take -> (B, C, m, m)
    minors = jnp.take(As.transpose(0, 2, 1), combos - 1, axis=1)
    dets = jnp.linalg.det(minors)                       # (B, C)
    signs = radic_sign(combos, m).astype(dets.dtype)    # (C,)
    terms = signs[None, :] * dets
    if valid is not None:
        terms = jnp.where(valid[None, :], terms, 0)
    return jnp.sum(terms, axis=1)


@functools.partial(jax.jit,
                   static_argnames=("total", "chunk", "kahan"))
def _radic_det_flat(A: jax.Array, table: jax.Array, total: int, chunk: int,
                    kahan: bool) -> jax.Array:
    m, n = A.shape
    num_chunks = -(-total // chunk)
    idx = jnp.arange(chunk, dtype=table.dtype)

    def body(c, carry):
        acc, comp = carry
        qs = c.astype(table.dtype) * chunk + idx
        valid = qs < total
        combos = unrank_jnp(jnp.where(valid, qs, 0), n, m, table)
        part = signed_minor_sum(A, combos, valid)
        if kahan:
            y = part - comp
            t = acc + y
            comp = (t - acc) - y
            acc = t
        else:
            acc = acc + part
        return acc, comp

    zero = jnp.zeros((), A.dtype)
    acc, _ = jax.lax.fori_loop(0, num_chunks, body, (zero, zero))
    return acc


def radic_det(A: jax.Array, *, chunk: int = 2048, kahan: bool = False,
              backend: Literal["jnp", "pallas"] = "jnp") -> jax.Array:
    """Radic determinant (paper Definition 3), rank-parallel flat mode.

    Single-device streaming evaluation; for mesh distribution see
    :func:`repro.core.distributed.radic_det_distributed`.  Requires
    ``C(n, m) < 2**31`` (int32 ranks) unless x64 is enabled — beyond that
    use the distributed grain mode (bigint grain starts).  Routed through
    the default :class:`~repro.core.engine.DetEngine`: the rank-width
    guards run at plan time, *before* backend dispatch, and the plan
    (Pascal table, clamped chunk, validated total) is cached per shape.

    Differentiable: the plan routes through a ``jax.custom_vjp`` whose
    backward pass replays the same rank-tile walk in cofactor form
    (DESIGN_GRAD.md), so ``jax.grad(radic_det)`` runs in O(chunk)
    backward memory instead of saving every minor as a residual.
    """
    from .engine import default_engine  # lazy: engine builds on this module
    A = jnp.asarray(A)
    m, n = A.shape
    return default_engine().plan(
        m, n, batched=False, dtype=A.dtype, chunk=chunk, kahan=kahan,
        backend=backend).differentiable(A)


def _radic_det_batched_flat_impl(As: jax.Array, table: jax.Array, total: int,
                                 chunk: int) -> jax.Array:
    B, m, n = As.shape
    num_chunks = -(-total // chunk)
    idx = jnp.arange(chunk, dtype=table.dtype)

    def body(c, acc):
        qs = c.astype(table.dtype) * chunk + idx
        valid = qs < total
        combos = unrank_jnp(jnp.where(valid, qs, 0), n, m, table)
        return acc + signed_minor_sum_batched(As, combos, valid)

    return jax.lax.fori_loop(0, num_chunks, body,
                             jnp.zeros((B,), As.dtype))


_radic_det_batched_flat = functools.partial(
    jax.jit, static_argnames=("total", "chunk"))(_radic_det_batched_flat_impl)


# ------------------------------------------------------------- VJP programs
# Cofactor-form backward pass (DESIGN_GRAD.md): for Radic's definition
# ∂det/∂A[i, j] = Σ_{q : j ∈ B_q} sign(B_q) · ∂det(A[:, B_q])/∂A[i, j],
# a signed sum of (m−1)-order minors over the *same* C(n, m) rank walk
# the forward pays.  Each chunk re-unranks its combinations exactly as
# the forward did and pulls the cotangent back through that chunk's
# minor-sum — no residuals are saved across chunks, so backward memory
# is O(chunk) like the forward, not O(total) like autodiff-of-scan.
@functools.partial(jax.jit, static_argnames=("total", "chunk"))
def _radic_det_grad_flat(A: jax.Array, ct: jax.Array, table: jax.Array,
                         total: int, chunk: int) -> jax.Array:
    m, n = A.shape
    num_chunks = -(-total // chunk)
    idx = jnp.arange(chunk, dtype=table.dtype)

    def body(c, g):
        qs = c.astype(table.dtype) * chunk + idx
        valid = qs < total
        combos = unrank_jnp(jnp.where(valid, qs, 0), n, m, table)
        _, pull = jax.vjp(lambda a: signed_minor_sum(a, combos, valid), A)
        (gA,) = pull(ct)
        return g + gA

    return jax.lax.fori_loop(0, num_chunks, body, jnp.zeros_like(A))


@functools.partial(jax.jit, static_argnames=("total", "chunk"))
def _radic_det_batched_grad_flat(As: jax.Array, cts: jax.Array,
                                 table: jax.Array, total: int,
                                 chunk: int) -> jax.Array:
    """Batched cofactor VJP: ``As (B, m, n)``, ``cts (B,)`` → ``(B, m, n)``.
    One shared unranking per chunk pulls back all B cotangents, the same
    amortization the batched forward gets."""
    B, m, n = As.shape
    num_chunks = -(-total // chunk)
    idx = jnp.arange(chunk, dtype=table.dtype)

    def body(c, g):
        qs = c.astype(table.dtype) * chunk + idx
        valid = qs < total
        combos = unrank_jnp(jnp.where(valid, qs, 0), n, m, table)
        _, pull = jax.vjp(
            lambda a: signed_minor_sum_batched(a, combos, valid), As)
        (gAs,) = pull(cts)
        return g + gAs

    return jax.lax.fori_loop(0, num_chunks, body, jnp.zeros_like(As))

# Same program, but the staged (B, m, n) batch buffer is donated: the
# serving tier stages each batch into a fresh device array that is dead
# the moment the dispatch returns, so on backends with real donation
# (TPU/GPU) XLA may alias it for scratch instead of allocating.  Math is
# untouched — donation is a buffer-aliasing hint, results bit-identical.
# The engine picks this lowering only when the backend supports donation
# (CPU ignores it with a compile-time warning).
_radic_det_batched_flat_donated = functools.partial(
    jax.jit, static_argnames=("total", "chunk"),
    donate_argnums=(0,))(_radic_det_batched_flat_impl)


def make_batched_evaluator(m: int, n: int, *, chunk: int = 2048,
                           backend: Literal["jnp", "pallas"] = "jnp",
                           mesh=None, axis_names=None,
                           batch_axis: str | None = None):
    """Bind the per-shape state of :func:`radic_det_batched` once.

    Returns the :class:`~repro.core.engine.DetPlan` for this shape — a
    callable ``evaluate(As: (B, m, n)) -> (B,)``.  The Pascal table, the
    C(n, m) rank count and the clamped chunk are computed at plan time,
    so a server dispatching many batches of the same shape
    (:mod:`repro.launch.det_queue`) pays the host-side combinatorics once
    per bucket instead of once per dispatch.  The plan enters the same
    jitted program as :func:`radic_det_batched`, so results are
    bit-identical to the one-shot path.  ``m > n`` is normalized to a
    jitted zeros *device* program for every backend/mesh configuration —
    not a host closure.

    The x64 flag is part of the plan key; flipping ``jax_enable_x64``
    after creation re-plans automatically on the next bind.
    """
    from .engine import default_engine  # lazy: engine builds on this module
    return default_engine().plan(
        m, n, batched=True, chunk=chunk, backend=backend, mesh=mesh,
        axis_names=axis_names, batch_axis=batch_axis)


def aot_compile_batched(m: int, n: int, capacity: int, dtype=np.float32, *,
                        chunk: int = 2048):
    """AOT-compile the jnp batched program for one ``(capacity, m, n)``.

    Lowers the *same* jitted function with the same table and statics as
    :func:`radic_det_batched`'s jnp path — the identical XLA program, so
    results are bit-identical to the traced-call path — but the
    per-dispatch python (jit-cache lookup, argument processing) is paid
    once here instead of on every call.  This is the dispatcher hot path
    of :class:`repro.launch.det_queue.DetQueue`.  Returns the
    :class:`~repro.core.engine.DetPlan`, callable as
    ``exe(As: (capacity, m, n) device array) -> (capacity,)``.  ``m > n``
    degenerates to the jitted zeros program (nothing to lower).
    """
    from .engine import default_engine  # lazy: engine builds on this module
    return default_engine().plan(
        m, n, batched=True, capacity=capacity, dtype=dtype, chunk=chunk)


def radic_det_batched(As: jax.Array, *, chunk: int = 2048,
                      backend: Literal["jnp", "pallas"] = "jnp",
                      mesh=None, axis_names=None,
                      batch_axis: str | None = None) -> jax.Array:
    """Radic determinants of a stack ``As (B, m, n)`` in one dispatch.

    The whole batch shares one (m, n) shape, hence one C(n, m) rank
    space, one Pascal table and one unranking per chunk — the per-rank
    combinatorics are amortized over B matrices (the GPU-batching
    strategy of Wei & Chen 2020 applied to Radic's definition).
    Heterogeneously-shaped inputs should be bucketed by shape first; see
    :mod:`repro.launch.det_serve`.  Returns ``(B,)``.

    With ``mesh`` the evaluation is sharded rank-space × batch over the
    mesh (see :func:`repro.core.distributed.radic_det_batched_distributed`).
    Repeated same-shape dispatches should bind the shape once via
    :func:`make_batched_evaluator`.
    """
    As = jnp.asarray(As)
    if As.ndim != 3:
        raise ValueError(f"expected (B, m, n), got {As.shape}")
    B, m, n = As.shape
    if B == 0:
        return jnp.zeros((0,), As.dtype)
    return make_batched_evaluator(
        m, n, chunk=chunk, backend=backend, mesh=mesh,
        axis_names=axis_names, batch_axis=batch_axis).differentiable(As)
