"""Sharded, elastic checkpointing.

Format: one directory per step —
    step_000123/
      manifest.json     (step, leaf names, shapes, dtypes, mesh note)
      host_<k>.npz      (this host's leaves, gathered to numpy)
    LATEST              (atomic pointer file)

Properties needed at 1000-node scale, reproduced faithfully at CPU scale:

* **atomic**: written to ``.tmp-`` then ``os.replace``d, so a crash mid-save
  never corrupts the latest checkpoint;
* **elastic**: the manifest stores only the *logical* tree; restore
  re-shards onto whatever mesh the new job has (any device count), via
  ``device_put`` with the caller's target shardings;
* **async**: ``save_async`` snapshots to host memory synchronously (one
  device_get) and writes in a background thread, so the train loop only
  blocks for the copy, not the I/O.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["CheckpointManager", "CheckpointMismatchError", "sweep_stale_tmp"]


class CheckpointMismatchError(ValueError):
    """Restore target tree disagrees with the checkpoint manifest.

    Raised (never ``assert``ed — asserts vanish under ``python -O``)
    when leaf names, shapes, or dtypes of the ``like`` tree do not
    match what the manifest recorded at save time.
    """


def sweep_stale_tmp(directory: str) -> list:
    """Remove leftover ``.tmp-*`` write dirs from a crashed save.

    A save that died between ``np.savez`` and ``os.replace`` leaves its
    ``.tmp-<tag>`` directory behind; the gc pass only matches finalized
    tags, so without this sweep they accumulate forever.  Called on
    manager/store init — by construction no writer is in flight then.
    Returns the swept names (for logging/tests).
    """
    swept = []
    try:
        entries = os.listdir(directory)
    except OSError:
        return swept
    for d in entries:
        p = os.path.join(directory, d)
        if d.startswith(".tmp-") and os.path.isdir(p):
            shutil.rmtree(p, ignore_errors=True)
            swept.append(d)
    return swept


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return names, vals, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        sweep_stale_tmp(directory)
        self._thread: threading.Thread | None = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree: Any, *, blocking: bool = True):
        names, vals, _ = _flatten(tree)
        host_vals = [np.asarray(jax.device_get(v)) for v in vals]

        def write():
            tag = f"step_{step:08d}"
            tmp = os.path.join(self.dir, f".tmp-{tag}")
            final = os.path.join(self.dir, tag)
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "host_0.npz"),
                     **{f"arr_{i}": v for i, v in enumerate(host_vals)})
            manifest = {
                "step": step,
                "names": names,
                "shapes": [list(v.shape) for v in host_vals],
                "dtypes": [str(v.dtype) for v in host_vals],
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            with open(os.path.join(self.dir, ".LATEST.tmp"), "w") as f:
                f.write(tag)
            os.replace(os.path.join(self.dir, ".LATEST.tmp"),
                       os.path.join(self.dir, "LATEST"))
            self._gc()

        # never let two writers touch the same tmp dir (e.g. an async save
        # of step N still in flight when a blocking save of N arrives)
        self.wait()
        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def save_async(self, step: int, tree: Any):
        self.save(step, tree, blocking=False)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        # LATEST holds the most *recently written* tag, which is not
        # necessarily the lexically-last step (an out-of-order low-step
        # save can land after a higher one) — never delete its target.
        latest = self._latest_tag()
        for d in steps[:-self.keep]:
            if d == latest:
                continue
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # -------------------------------------------------------------- restore
    def _latest_tag(self) -> str | None:
        p = os.path.join(self.dir, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return f.read().strip()

    def latest_step(self) -> int | None:
        tag = self._latest_tag()
        if tag is None or not os.path.isdir(os.path.join(self.dir, tag)):
            return None
        return int(tag.split("_")[1])

    def restore(self, like: Any, step: int | None = None,
                shardings: Any = None) -> tuple[int, Any] | None:
        """Restore into the structure of ``like`` (pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        NamedShardings for elastic placement on the *current* mesh."""
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                return None
        tag = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(tag, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(tag, "host_0.npz"))
        vals = [data[f"arr_{i}"] for i in range(len(manifest["names"]))]
        names, like_vals, treedef = _flatten(like)
        if names != manifest["names"]:
            raise CheckpointMismatchError(
                "checkpoint/param tree name mismatch:\n"
                f"ckpt: {manifest['names'][:5]}...\nlike: {names[:5]}...")
        # Names alone pass a transposed-leaf corruption — check each
        # target leaf's shape and dtype against the manifest too.
        for name, lv, shape, dtype in zip(
                names, like_vals, manifest["shapes"], manifest["dtypes"]):
            l_shape = getattr(lv, "shape", None)
            l_dtype = getattr(lv, "dtype", None)
            if l_shape is None or l_dtype is None:
                continue    # bare python leaf: nothing to validate
            if list(l_shape) != list(shape) or str(l_dtype) != dtype:
                raise CheckpointMismatchError(
                    f"checkpoint leaf {name!r}: checkpoint has "
                    f"shape={tuple(shape)} dtype={dtype}, restore target "
                    f"expects shape={tuple(l_shape)} dtype={l_dtype}")
        tree = jax.tree_util.tree_unflatten(treedef, vals)
        if shardings is not None:
            tree = jax.tree.map(
                lambda v, s: jax.device_put(v, s), tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return step, tree
