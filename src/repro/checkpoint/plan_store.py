"""Persistent `DetPlan` artifact store — the durable half of warm-start.

Layout (see DESIGN_PERSIST.md): one directory per plan family under the
store root, named by the plan key's :func:`stable_key_hash`::

    plan_<16-hex>/
      manifest.json   (schema, env stamp, plan meta, blob names)
      fwd.bin         (optional: serialized AOT forward executable)
      grad.bin        (optional: serialized AOT gradient executable)

Writes reuse :class:`CheckpointManager`'s atomicity discipline verbatim:
everything lands in a ``.tmp-<name>`` sibling first and is published with
one ``os.replace``, so a crash mid-write never corrupts a published
entry; stale ``.tmp-`` leftovers are swept on init (same
:func:`sweep_stale_tmp` the manager uses).

The store is deliberately **stdlib-pure** (no jax, no numpy): callers
hand it plain-JSON metadata and opaque ``bytes`` blobs.  Blob values may
also be zero-arg callables producing bytes — evaluated on the writer
thread, so expensive serialization (``jax.export``) never runs on the
dispatch path.  Validation is by env stamp: a manifest whose ``env``
(jax version, backend) or schema differs from this process is treated as
a miss, never an error — persistence is an acceleration, not a
correctness dependency.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from collections import deque

from .manager import sweep_stale_tmp

__all__ = ["PlanStore", "SCHEMA_VERSION"]

SCHEMA_VERSION = 1


class PlanStore:
    """Atomic on-disk map ``key_hash -> (meta, blobs)`` with async writes.

    Thread-safe: reads touch only the filesystem (published entries are
    immutable snapshots thanks to ``os.replace``); the write queue and
    its counters are guarded state.
    """

    # reprolint lock-discipline registry: the write queue is shared
    # between every planner thread and the background writer.
    _GUARDED_BY = {
        "_pending": ("_lock", "_cv"),
        "_busy": ("_lock", "_cv"),
        "_writer": ("_lock", "_cv"),
        "_closed": ("_lock", "_cv"),
        "_written": ("_lock", "_cv"),
        "_write_errors": ("_lock", "_cv"),
    }

    def __init__(self, directory: str, *, env: dict | None = None):
        self.dir = str(directory)
        os.makedirs(self.dir, exist_ok=True)
        sweep_stale_tmp(self.dir)
        # env stamp: plain strings only, compared for equality on read
        self.env = {str(k): str(v) for k, v in dict(env or {}).items()}
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._pending: deque = deque()
        self._busy = False
        self._writer: threading.Thread | None = None
        self._closed = False
        self._written = 0
        self._write_errors = 0

    # --------------------------------------------------------------- naming
    @staticmethod
    def entry_name(key_hash: int) -> str:
        return f"plan_{int(key_hash):016x}"

    # ---------------------------------------------------------------- write
    def put(self, key_hash: int, meta: dict, blobs: dict | None = None):
        """Synchronous atomic write (tests / explicit flush points)."""
        self._write_entry(self.entry_name(key_hash), dict(meta),
                          dict(blobs or {}))

    def put_async(self, key_hash: int, meta: dict,
                  blobs: dict | None = None):
        """Enqueue a write for the background thread; never blocks on IO.

        ``blobs`` values may be bytes or zero-arg callables returning
        bytes (or None to skip) — callables run on the writer thread.
        """
        job = (self.entry_name(key_hash), dict(meta), dict(blobs or {}))
        with self._cv:
            if self._closed:
                return
            self._pending.append(job)
            if self._writer is None:
                self._writer = threading.Thread(
                    target=self._drain, name="plan-store-writer", daemon=True)
                self._writer.start()
            self._cv.notify_all()

    def _drain(self):
        while True:
            with self._cv:
                while not self._pending and not self._closed:
                    self._cv.wait()
                if not self._pending:       # closed and drained
                    return
                name, meta, blobs = self._pending.popleft()
                self._busy = True
            ok = True
            try:
                self._write_entry(name, meta, blobs)
            except Exception:   # noqa: BLE001 — persistence must not kill
                ok = False      # the process; the entry is simply absent
            with self._cv:
                self._busy = False
                if ok:
                    self._written += 1
                else:
                    self._write_errors += 1
                self._cv.notify_all()

    def _write_entry(self, name: str, meta: dict, blobs: dict):
        tmp = os.path.join(self.dir, f".tmp-{name}")
        final = os.path.join(self.dir, name)
        os.makedirs(tmp, exist_ok=True)
        written_blobs = []
        for bname, blob in blobs.items():
            if callable(blob):              # deferred serialization
                blob = blob()
            if blob is None:                # serializer declined (no
                continue                    # jax.export): metadata-only
            with open(os.path.join(tmp, f"{bname}.bin"), "wb") as f:
                f.write(blob)
            written_blobs.append(bname)
        manifest = {"schema": SCHEMA_VERSION, "env": self.env,
                    "meta": meta, "blobs": sorted(written_blobs)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)

    def flush(self):
        """Block until every enqueued write has been attempted."""
        with self._cv:
            while self._pending or self._busy:
                self._cv.wait()

    def close(self):
        """Drain outstanding writes and stop the writer thread."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
            w = self._writer
        if w is not None:
            w.join(timeout=30)

    # ----------------------------------------------------------------- read
    def _load_manifest(self, final: str) -> dict | None:
        try:
            with open(os.path.join(final, "manifest.json")) as f:
                manifest = json.load(f)
        except (OSError, ValueError):
            return None
        if not isinstance(manifest, dict):
            return None
        if manifest.get("schema") != SCHEMA_VERSION:
            return None      # future/foreign layout: miss, not error
        if manifest.get("env") != self.env:
            return None      # other jax/backend: plans don't transfer
        if not isinstance(manifest.get("meta"), dict):
            return None
        return manifest

    def get(self, key_hash: int) -> tuple | None:
        """``(meta, blobs)`` for a stored family, or None on any miss —
        absent entry, schema/env mismatch, unreadable blob."""
        final = os.path.join(self.dir, self.entry_name(key_hash))
        manifest = self._load_manifest(final)
        if manifest is None:
            return None
        blobs = {}
        for bname in manifest.get("blobs", []):
            try:
                with open(os.path.join(final, f"{bname}.bin"), "rb") as f:
                    blobs[bname] = f.read()
            except OSError:
                return None
        return dict(manifest["meta"]), blobs

    def families(self) -> list:
        """Metadata of every valid stored family (prefill enumeration)."""
        out = []
        try:
            entries = sorted(os.listdir(self.dir))
        except OSError:
            return out
        for d in entries:
            if not d.startswith("plan_"):
                continue
            manifest = self._load_manifest(os.path.join(self.dir, d))
            if manifest is not None:
                out.append(dict(manifest["meta"]))
        return out

    def stats(self) -> dict:
        entries = sum(1 for d in os.listdir(self.dir)
                      if d.startswith("plan_"))
        with self._cv:
            return {"entries": entries, "written": self._written,
                    "write_errors": self._write_errors,
                    "pending": len(self._pending)}
