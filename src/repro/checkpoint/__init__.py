from .manager import (CheckpointManager, CheckpointMismatchError,
                      sweep_stale_tmp)
from .plan_store import PlanStore

__all__ = ["CheckpointManager", "CheckpointMismatchError",
           "sweep_stale_tmp", "PlanStore"]
