"""Synthetic deterministic LM data pipeline.

Production-shaped: host-sharded (each process generates only its slice of
the global batch), deterministic in (seed, step, shard) so restarts resume
bit-identically mid-stream, with a background double-buffered prefetcher.
The "dataset" is a reproducible token stream with local n-gram structure
(so a ~100M model actually learns and the example-run loss curve means
something) — swapping in a real tokenized corpus only changes
``_tokens_for``.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

__all__ = ["DataConfig", "SyntheticLMData", "Prefetcher"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_shards: int = 1      # data-parallel host shards
    shard_id: int = 0


class SyntheticLMData:
    """Deterministic structured token stream (order-2 markov-ish)."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.num_shards == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_shards
        # a fixed random "transition" table gives the stream learnable
        # structure; identical on every host (derived from seed only)
        r = np.random.default_rng(cfg.seed)
        self._next = r.integers(0, cfg.vocab_size,
                                size=(cfg.vocab_size, 4), dtype=np.int32)

    def _tokens_for(self, step: int) -> np.ndarray:
        cfg = self.cfg
        r = np.random.default_rng(
            (cfg.seed, step, self.cfg.shard_id, 0xDA7A))
        B, S = self.local_batch, cfg.seq_len
        out = np.empty((B, S), np.int32)
        out[:, 0] = r.integers(0, cfg.vocab_size, size=B)
        branch = r.integers(0, 4, size=(B, S))
        noise = r.random((B, S))
        rand_tok = r.integers(0, cfg.vocab_size, size=(B, S))
        for t in range(1, S):
            follow = self._next[out[:, t - 1], branch[:, t]]
            out[:, t] = np.where(noise[:, t] < 0.1, rand_tok[:, t], follow)
        return out

    def batch(self, step: int) -> dict:
        toks = self._tokens_for(step)
        return {"tokens": toks, "labels": toks.copy()}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background thread keeping ``depth`` batches ready."""

    def __init__(self, source: SyntheticLMData, start_step: int = 0,
                 depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step

        def work():
            s = start_step
            while not self._stop.is_set():
                b = source.batch(s)
                while not self._stop.is_set():
                    try:
                        self._q.put((s, b), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                s += 1

        self._t = threading.Thread(target=work, daemon=True)
        self._t.start()

    def next(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self):
        self._stop.set()
