from .pipeline import DataConfig, Prefetcher, SyntheticLMData
__all__ = ["DataConfig", "Prefetcher", "SyntheticLMData"]
