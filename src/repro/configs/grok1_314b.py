"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, vocab_size=131072,
    n_experts=8, top_k=2, moe_impl="scatter",
    attn_logit_softcap=30.0, final_logit_softcap=30.0,
    rope_theta=10_000.0, norm_eps=1e-5,
    param_dtype="bfloat16", dtype="bfloat16", fsdp_over_pod=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=128, vocab_size=512, n_experts=4, top_k=2,
        param_dtype="float32", dtype="float32", remat=False,
        fsdp_over_pod=False)
