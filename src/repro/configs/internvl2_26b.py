"""internvl2-26b [vlm] — InternViT + InternLM2-20B [arXiv:2404.16821; hf].

Backbone only: the vision tower is a STUB; input_specs feeds 256
precomputed patch embeddings per image as a prefix (DESIGN.md §5)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=92553,
    prefix_embeds=True, n_patches=256,
    rope_theta=1_000_000.0, norm_eps=1e-5,
    param_dtype="bfloat16", dtype="bfloat16",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=128, vocab_size=512, n_patches=4, param_dtype="float32",
        dtype="float32", remat=False)
