"""hymba-1.5b [hybrid] — parallel attn+mamba heads [arXiv:2411.13676; hf].

Adaptations (DESIGN.md §5): meta-tokens stubbed; SWA window 1024 with a
full-attention layer every 16 (the paper uses first/middle/last full)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    attn_window=1024, local_global_period=16,
    rope_theta=10_000.0, norm_eps=1e-5,
    param_dtype="bfloat16", dtype="bfloat16",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, ssm_state=16, ssm_head_dim=16,
        ssm_chunk=8, attn_window=8, local_global_period=2,
        param_dtype="float32", dtype="float32", remat=False)
