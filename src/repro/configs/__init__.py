from .registry import ARCHS, get_config, list_archs
from .shapes import SHAPES, applicable, input_specs, model_flops
__all__ = ["ARCHS", "get_config", "list_archs", "SHAPES", "applicable",
           "input_specs", "model_flops"]
