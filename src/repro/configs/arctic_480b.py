"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base].

dense_residual_ff=7168 derived to match the published ~10B dense share
(assignment specifies expert d_ff only) — DESIGN.md §5."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=4864, vocab_size=32000,
    n_experts=128, top_k=2, dense_residual_ff=7168, moe_impl="scatter",
    rope_theta=10_000.0, norm_eps=1e-5,
    param_dtype="bfloat16", dtype="bfloat16", fsdp_over_pod=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=96, vocab_size=512, n_experts=8, top_k=2,
        dense_residual_ff=64, param_dtype="float32", dtype="float32",
        remat=False, fsdp_over_pod=False)
