"""--arch registry: name -> config module."""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

__all__ = ["ARCHS", "get_config", "list_archs"]

ARCHS = {
    "yi-34b": "yi_34b",
    "gemma2-9b": "gemma2_9b",
    "llama3-405b": "llama3_405b",
    "llama3-8b": "llama3_8b",
    "hymba-1.5b": "hymba_1_5b",
    "arctic-480b": "arctic_480b",
    "grok-1-314b": "grok1_314b",
    "mamba2-1.3b": "mamba2_1_3b",
    "internvl2-26b": "internvl2_26b",
    "whisper-medium": "whisper_medium",
}


# §Perf winners (EXPERIMENTS.md): per-arch beyond-baseline knob sets,
# measured on the dry-run roofline terms.  get_config(optimized=True)
# applies them; the plain CONFIG stays the paper/baseline-faithful one so
# both remain reproducible.
OPTIMIZED_OVERRIDES: dict[str, dict] = {
    "llama3-405b": dict(attn_chunk=1024, loss_chunk=1024, seq_shard=True),
    "llama3-8b": dict(attn_chunk=1024, loss_chunk=1024, seq_shard=True),
    "yi-34b": dict(attn_chunk=1024, loss_chunk=1024, seq_shard=True),
    "gemma2-9b": dict(attn_chunk=1024, loss_chunk=1024),
    "internvl2-26b": dict(attn_chunk=1024, loss_chunk=1024,
                          seq_shard=True),
    "arctic-480b": dict(moe_impl="onehot", attn_chunk=1024,
                        loss_chunk=1024),
    "grok-1-314b": dict(moe_impl="onehot", attn_chunk=1024,
                        loss_chunk=1024),
    "hymba-1.5b": dict(attn_chunk=1024, loss_chunk=1024),
    "mamba2-1.3b": dict(loss_chunk=1024),
    "whisper-medium": dict(attn_chunk=1024),
}


def get_config(name: str, smoke: bool = False,
               optimized: bool = False) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; options: {list(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[name]}")
    cfg = mod.smoke() if smoke else mod.CONFIG
    if optimized and not smoke:
        cfg = cfg.replace(**OPTIMIZED_OVERRIDES.get(name, {}))
    return cfg


def list_archs() -> list[str]:
    return list(ARCHS)
