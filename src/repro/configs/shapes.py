"""Assigned input-shape sets + ShapeDtypeStruct stand-ins for the dry-run.

Four shapes per LM architecture (40 cells total):
  train_4k     seq 4096   × global_batch 256   (train_step)
  prefill_32k  seq 32768  × global_batch 32    (prefill_step)
  decode_32k   KV 32768   × global_batch 128   (decode_step, 1 new token)
  long_500k    KV 524288  × global_batch 1     (decode_step; sub-quadratic
                                                archs only)

``input_specs`` allocates nothing — pure ShapeDtypeStructs, weak-type
correct and shardable, exactly the shannon/kernels pattern.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import build_model
from repro.models.config import ModelConfig

__all__ = ["SHAPES", "Shape", "applicable", "input_specs", "abstract_params",
           "abstract_cache", "model_flops"]


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    kind: str        # train | prefill | decode
    seq: int         # context length (training seq or KV length)
    batch: int       # global batch


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """Whether this (arch, shape) cell runs; reason recorded if skipped."""
    if shape_name == "long_500k" and cfg.family not in \
            SUBQUADRATIC_FAMILIES:
        return False, ("needs sub-quadratic attention; "
                       f"{cfg.name} is full-attention ({cfg.family})")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStructs for the step's *data* inputs (not params/cache)."""
    sh = SHAPES[shape_name]
    B, S = sh.batch, sh.seq
    tok = jnp.int32
    if sh.kind == "train":
        specs = {"tokens": _sds((B, S), tok), "labels": _sds((B, S), tok)}
        if cfg.prefix_embeds:  # patches count against the 4k context
            specs["tokens"] = _sds((B, S - cfg.n_patches), tok)
            specs["labels"] = _sds((B, S - cfg.n_patches), tok)
            specs["prefix_embeds"] = _sds((B, cfg.n_patches, cfg.d_model),
                                          cfg.adtype)
        if cfg.family == "audio":
            specs["frame_embeds"] = _sds((B, cfg.n_frames, cfg.d_model),
                                         cfg.adtype)
        return specs
    if sh.kind == "prefill":
        specs = {"tokens": _sds((B, S), tok)}
        if cfg.prefix_embeds:
            specs["tokens"] = _sds((B, S - cfg.n_patches), tok)
            specs["prefix_embeds"] = _sds((B, cfg.n_patches, cfg.d_model),
                                          cfg.adtype)
        if cfg.family == "audio":
            specs["frame_embeds"] = _sds((B, cfg.n_frames, cfg.d_model),
                                         cfg.adtype)
        return specs
    # decode: one new token against a seq-length cache
    return {"tokens": _sds((B, 1), tok)}


def abstract_params(cfg: ModelConfig):
    model = build_model(cfg)
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def abstract_cache(cfg: ModelConfig, shape_name: str):
    sh = SHAPES[shape_name]
    model = build_model(cfg)
    return jax.eval_shape(
        lambda: model.init_cache(sh.batch, sh.seq))


# ---------------------------------------------------------------------------
# MODEL_FLOPS for the roofline's usefulness ratio.
# ---------------------------------------------------------------------------

def param_count(cfg: ModelConfig) -> int:
    import math
    params = abstract_params(cfg)
    return sum(math.prod(p.shape) for p in jax.tree.leaves(params))


def active_param_count(cfg: ModelConfig) -> int:
    """MoE: only top-k experts' weights count per token."""
    n = param_count(cfg)
    if cfg.family != "moe":
        return n
    per_expert = 3 * cfg.d_model * cfg.d_ff
    inactive = cfg.n_layers * (cfg.n_experts - cfg.top_k) * per_expert
    return n - inactive


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    """6·N·D (train) / 2·N·D (inference fwd) with N = active params."""
    sh = SHAPES[shape_name]
    n_active = active_param_count(cfg)
    if sh.kind == "train":
        tokens = sh.batch * sh.seq
        return 6.0 * n_active * tokens
    if sh.kind == "prefill":
        tokens = sh.batch * sh.seq
        return 2.0 * n_active * tokens
    return 2.0 * n_active * sh.batch  # decode: one token per row
