"""The paper's own workload: Radic determinant of an m×n matrix.

Not an LM architecture — configures the core library + kernels for the
benchmark/driver scripts."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class RadicConfig:
    m: int = 5
    n: int = 24
    mode: str = "flat"            # flat | grains
    backend: str = "pallas"       # pallas | jnp
    grains_per_device: int = 4
    chunk: int = 2048
    tile: int = 256
    kahan: bool = False


CONFIG = RadicConfig()


def smoke() -> RadicConfig:
    return RadicConfig(m=3, n=10, chunk=32, tile=16, grains_per_device=2)
