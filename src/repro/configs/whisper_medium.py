"""whisper-medium [audio] — enc-dec, conv frontend STUB [arXiv:2212.04356].

"24L" read as 24 encoder + 24 decoder layers (the published medium
config).  kv=16 with 16 heads => plain MHA.  Backbone adaptations
(DESIGN.md §5): GLU MLP + RMSNorm + RoPE in place of whisper's
GELU-MLP/LayerNorm/learned-abs-pos (backbone-stub semantics); decoder
positions extended to the assigned 32k shapes."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=51865,
    enc_dec=True, n_enc_layers=24, n_frames=1500,
    act="gelu", norm_eps=1e-5,
    param_dtype="bfloat16", dtype="bfloat16",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=512, n_frames=12,
        param_dtype="float32", dtype="float32", remat=False)
