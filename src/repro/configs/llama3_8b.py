"""llama3-8b [dense] — GQA 128k vocab [arXiv:2407.21783]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256,
    rope_theta=500_000.0, norm_eps=1e-5,
    param_dtype="bfloat16", dtype="bfloat16",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=128, vocab_size=512, param_dtype="float32", dtype="float32",
        remat=False)
