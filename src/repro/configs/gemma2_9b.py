"""gemma2-9b [dense] — local+global alternating, logit softcaps
[arXiv:2408.00118; hf].  head_dim=256 per HF config (16*256=4096 != d_model)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000,
    rope_theta=10_000.0, norm_eps=1e-6, act="gelu",
    attn_window=4096, local_global_period=2,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    post_block_norm=True, scale_embeddings=True, tie_embeddings=True,
    param_dtype="bfloat16", dtype="bfloat16",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, attn_window=8,
        param_dtype="float32", dtype="float32", remat=False)
