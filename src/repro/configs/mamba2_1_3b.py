"""mamba2-1.3b [ssm] — SSD state-space duality [arXiv:2405.21060].

Attention-free: pure SSD blocks (d_inner=4096, 64 heads of dim 64,
d_state=128, chunk 256 — paper-standard)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=1, head_dim=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
    ssm_chunk=256, norm_eps=1e-5, tie_embeddings=True,
    param_dtype="bfloat16", dtype="bfloat16",
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, vocab_size=512, ssm_state=16,
        ssm_head_dim=16, ssm_chunk=8, param_dtype="float32",
        dtype="float32", remat=False)
