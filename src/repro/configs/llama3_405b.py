"""llama3-405b [dense] — GQA 128k vocab [arXiv:2407.21783]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, head_dim=128,
    d_ff=53248, vocab_size=128256,
    rope_theta=500_000.0, norm_eps=1e-5,
    param_dtype="bfloat16", dtype="bfloat16", fsdp_over_pod=True,
)


def smoke() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=192, vocab_size=512, param_dtype="float32", dtype="float32",
        remat=False, fsdp_over_pod=False)
