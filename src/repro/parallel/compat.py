"""Version-portable parallel primitives — the single jax-compat seam.

jax has renamed its per-device SPMD surface twice across the versions this
repo must run on; every call site in the repo goes through this module so
the rest of the codebase is spelled one way.

Compat policy (the supported spellings):

* ``shard_map`` — resolves, in order:

  1. ``jax.shard_map`` (jax >= 0.5 public API), keyword-only params,
     replication check spelled ``check_vma``;
  2. ``jax.experimental.shard_map.shard_map`` (jax 0.4.x), positional
     params, replication check spelled ``check_rep``.

  The wrapper accepts *either* ``check_vma`` or ``check_rep`` and
  translates to whatever the resolved function understands.  If the
  native function understands neither (a future rename), the flag is
  dropped: the check is purely diagnostic, never load-bearing.

* ``pvary`` — marks a replicated value as device-varying so it can enter
  collectives under the new varying-manual-axes (VMA) type system.
  Resolves ``jax.lax.pvary`` → ``jax.lax.pcast(..., to="varying")``
  (transitional spelling) → identity (jax 0.4.x has no VMA types, so
  replicated values flow into collectives unannotated).

* ``psum_scalar`` — ``pvary`` + one ``psum`` per mesh axis name.  This is
  the repo's reduction idiom for grain/chunk partials; keeping it here
  means call sites never touch ``jax.lax.psum`` axis plumbing directly.

No other module may read ``jax.shard_map`` / ``jax.experimental.
shard_map`` / ``jax.lax.pvary`` / ``jax.lax.pcast`` — tests enforce the
``shard_map`` half of that by grepping the source tree.
"""

from __future__ import annotations

import inspect
import os
from typing import Callable, Sequence

import jax

__all__ = ["shard_map", "pvary", "psum_scalar", "axis_size",
           "native_shard_map_source", "export_supported",
           "serialize_lowered", "deserialize_exported",
           "enable_compilation_cache"]


def _native_shard_map() -> tuple[Callable, str]:
    """The installed jax's shard_map and where it came from.

    Resolved per call (it is trace-time only, cost is negligible) so tests
    can monkeypatch either spelling.
    """
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn, "jax.shard_map"
    from jax.experimental import shard_map as _sm  # jax 0.4.x
    return _sm.shard_map, "jax.experimental.shard_map.shard_map"


def native_shard_map_source() -> str:
    """Which spelling this process resolved to (for logs/diagnostics)."""
    return _native_shard_map()[1]


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check_vma: bool | None = None,
              check_rep: bool | None = None, **kwargs):
    """Portable ``shard_map``: maps ``f`` over shards of a mesh.

    Accepts the replication-check flag under either historical name
    (``check_vma`` — new jax; ``check_rep`` — jax 0.4.x) and forwards it
    under whichever name the installed jax understands.  ``f`` is the only
    positional argument, so ``functools.partial(shard_map, mesh=...,
    in_specs=..., out_specs=...)`` works as a decorator on every version.
    """
    if check_vma is not None and check_rep is not None:
        raise TypeError("pass check_vma or check_rep, not both")
    check = check_vma if check_vma is not None else check_rep
    fn, _ = _native_shard_map()
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
    if check is not None:
        params = inspect.signature(fn).parameters
        if "check_vma" in params:
            kw["check_vma"] = check
        elif "check_rep" in params:
            kw["check_rep"] = check
        # else: diagnostic flag unknown to this jax — drop it.
    return fn(f, **kw)


def pvary(x, axis_names: Sequence[str]):
    """Mark ``x`` as varying over ``axis_names`` inside shard_map.

    Identity on jax versions without the VMA type system.
    """
    axes = tuple(axis_names)
    if not axes:
        return x
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axes)
    if hasattr(jax.lax, "pcast"):  # transitional spelling
        return jax.lax.pcast(x, axes, to="varying")
    return x


def axis_size(axis_name: str):
    """Size of a bound mesh axis (``jax.lax.axis_size`` is new-jax only).

    The jax 0.4.x fallback ``psum(1, axis)`` yields the same value as a
    (constant) array, which every call site uses purely arithmetically.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


# --------------------------------------------------------- AOT export seam
#
# ``jax.export`` (serialize a lowered/compiled program to bytes, reload it
# in another process) stabilized across 0.4.x under several spellings and
# may be absent entirely.  The plan store treats export as a pure
# acceleration: when any of these return None the store falls back to
# metadata-only persistence (re-lower from cached statics), which is
# always correct — so every failure path below degrades, never raises.
#
# Blob reload is additionally **opt-in** (``REPRO_PLAN_BLOBS=1``): on the
# pinned 0.4.x CPU leg a reloaded executable whose program contains LAPACK
# custom calls (every ``jnp.linalg`` LU — i.e. every determinant program in
# this repo) segfaults at first call, because the serialized form bakes in
# native custom-call pointers that do not survive the process boundary.
# That failure is a hard crash, not an exception, so it cannot be caught
# and degraded at use time — it has to be gated off up front.  The safe
# cross-process compile-skip on such legs is the XLA persistent
# compilation cache (:func:`enable_compilation_cache` below), which is
# content-addressed and re-links custom calls at load.

_BLOBS_ENV = "REPRO_PLAN_BLOBS"


def _export_module():
    if os.environ.get(_BLOBS_ENV, "") != "1":
        return None
    try:
        import jax.export as mod  # real submodule since 0.4.30; a plain
        # getattr on the lazily-populated ``jax`` namespace misses it
    except Exception:
        return None
    if hasattr(mod, "export") and hasattr(mod, "deserialize"):
        return mod
    return None


def export_supported() -> bool:
    """Whether this jax can serialize AOT executables for the plan store."""
    return _export_module() is not None


def enable_compilation_cache(path: str) -> bool:
    """Point jax's persistent compilation cache at ``path``; True if on.

    The plan store calls this with ``<persist_dir>/xla-cache`` so that a
    warm-started process skips the XLA compile of every program any prior
    process against the same store already built — the compile-skip
    channel that works even where blob reload is unsafe (see above).
    Idempotent and deferential: an already-configured cache dir (user or
    earlier engine) is left untouched, and missing config options on
    older jax degrade to False, never raise.
    """
    try:
        if jax.config.jax_compilation_cache_dir is not None:
            return True
        jax.config.update("jax_compilation_cache_dir", path)
    except Exception:
        return False
    try:
        # Plan-family compiles are the whole point of the cache here, and
        # some are quick — cache everything, not just slow compiles.
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
    except Exception:
        pass  # thresholds unavailable: defaults still cache slow compiles
    try:
        # jax latches cache-off at the first compile of the process; if
        # anything compiled before us (warm-up jits, an import-time
        # trace), the dir we just set is silently ignored.  reset_cache
        # drops the latch so the next compile re-reads the config.
        from jax._src import compilation_cache as _cc
        _cc.reset_cache()
    except Exception:
        pass  # no latch on this jax: the config update alone suffices
    return True


def serialize_lowered(fn, *args) -> bytes | None:
    """Serialize jitted ``fn`` specialized to ``args`` → bytes, or None.

    ``args`` are abstract specs (``ShapeDtypeStruct``) or concrete
    arrays; the serialized form captures the StableHLO of the same
    program ``fn.lower(*args).compile()`` would build, so a reload
    compiles to a bit-identical executable.
    """
    mod = _export_module()
    if mod is None:
        return None
    try:
        # .serialize() hands back a bytearray on some jax legs; the plan
        # store's blob contract is immutable plain bytes
        return bytes(mod.export(fn)(*args).serialize())
    except Exception:
        return None


def deserialize_exported(blob: bytes):
    """Reload a :func:`serialize_lowered` blob → callable, or None.

    The returned callable re-traces through ``exported.call`` under jit;
    callers treat None (unsupported jax, stale/foreign blob) as a store
    miss and re-lower from statics instead.
    """
    mod = _export_module()
    if mod is None:
        return None
    try:
        exported = mod.deserialize(blob)
        return jax.jit(exported.call)
    except Exception:
        return None


def psum_scalar(x, axis_names: Sequence[str]):
    """Sum ``x`` over every named mesh axis (inside shard_map).

    Works on replicated *or* varying operands on both old and new jax:
    the operand is first ``pvary``'d (no-op where unsupported/already
    varying), then reduced one axis at a time.
    """
    axes = tuple(axis_names)
    if not axes:
        return x
    x = pvary(x, axes)
    for ax in axes:
        x = jax.lax.psum(x, ax)
    return x
