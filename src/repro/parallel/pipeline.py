"""GPipe-style pipeline parallelism over a mesh axis.

Optional at the assigned mesh sizes (TP×DP covers 256–512 chips), but a
1000+-node deployment of the 405B-class configs wants a stage axis.  The
implementation is the standard shard_map + ppermute ring:

* layer-stacked params are split into S contiguous stages; device s holds
  stage s (sharded by the caller's param rules within the stage);
* the global batch is cut into M microbatches; at schedule step t device
  s computes microbatch t−s (when 0 ≤ t−s < M) and passes its activation
  to s+1 via `collective_permute` — the classic (S+M−1)-step GPipe fill/
  drain diagram with bubble fraction (S−1)/(S+M−1).

`pipeline_apply` is jit/grad-compatible (pure lax ops).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .compat import shard_map

__all__ = ["gpipe_schedule", "pipeline_apply", "bubble_fraction"]


def gpipe_schedule(n_stages: int, n_micro: int):
    """[(step, stage, microbatch)] for the fill/drain schedule."""
    out = []
    for t in range(n_stages + n_micro - 1):
        for s in range(n_stages):
            m = t - s
            if 0 <= m < n_micro:
                out.append((t, s, m))
    return out


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_stages + n_micro - 1)


def pipeline_apply(stage_fn: Callable, stage_params, x, *, mesh: Mesh,
                   stage_axis: str, n_micro: int):
    """Run ``y = stage_{S-1}(...stage_0(x))`` pipelined over ``stage_axis``.

    ``stage_params``: pytree whose leaves have a leading stage dim S
    (sharded over ``stage_axis``).  ``x``: (n_micro, micro_batch, ...)
    microbatched input, replicated over the stage axis.  Returns the
    final-stage output for every microbatch, replicated.
    """
    S = mesh.shape[stage_axis]
    assert x.shape[0] == n_micro

    @functools.partial(
        shard_map, mesh=mesh, check_vma=False,
        in_specs=(P(stage_axis), P()), out_specs=P())
    def run(params_local, xs):
        # params_local leaves: (1, ...) — this device's stage
        p = jax.tree.map(lambda q: q[0], params_local)
        sid = jax.lax.axis_index(stage_axis)
        T = S + n_micro - 1
        buf = jnp.zeros_like(xs[0])          # activation entering stage
        outs = jnp.zeros_like(xs)

        def step(t, carry):
            buf, outs = carry
            m = t - sid                       # microbatch at this stage
            active = (m >= 0) & (m < n_micro)
            # stage 0 injects its own microbatch from the input stream
            inj = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False)
            h = jnp.where(sid == 0, inj, buf)
            y = stage_fn(p, h)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # last stage records its finished microbatch
            rec = (sid == S - 1) & active
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(rec, y,
                                jax.lax.dynamic_index_in_dim(
                                    outs, jnp.clip(m, 0, n_micro - 1), 0,
                                    keepdims=False)),
                jnp.clip(m, 0, n_micro - 1), 0)
            # pass activations down the ring (stage s -> s+1)
            perm = [(i, (i + 1) % S) for i in range(S)]
            buf = jax.lax.ppermute(y, stage_axis, perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, T, step, (buf, outs))
        # only the last stage holds real outputs; share them
        outs = jax.lax.psum(
            jnp.where(sid == S - 1, outs, jnp.zeros_like(outs)),
            stage_axis)
        return outs

    return run(stage_params, x)
