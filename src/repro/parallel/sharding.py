"""Logical→physical sharding rule engine.

Models annotate tensors with *logical* axis names ("batch", "mlp", …).
A :class:`ShardingRules` maps logical names to physical mesh axes, with a
**divisibility fallback**: if a dim doesn't divide over the mapped axes,
the engine drops axes (longest-suffix first) until it does, and records
the fallback so the dry-run log can show it (never silent).

Two rule tables per run: one for parameters (TP + FSDP placement) and one
for activations (batch/seq placement).  Models call :func:`constraint`
with logical names; outside a `use_rules` context it is the identity, so
the same model code runs unsharded on one CPU device.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import math
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "use_rules", "constraint", "spec_for",
           "sharding_for", "ACT_RULES_SMALL", "ACT_RULES_LARGE",
           "PARAM_RULES_SMALL", "PARAM_RULES_LARGE", "current_rules"]

# ---------------------------------------------------------------------------
# Default rule tables.  "small" = replicate params across pods (DP over pod),
# "large" = FSDP params over (pod, data) as well (405B-class).
# ---------------------------------------------------------------------------

ACT_RULES_SMALL: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,              # "model" under sequence/context parallelism
    "kv_seq": "model",        # decode KV cache length (context parallel)
    "embed": None,
    "qdim": "model",
    "kvdim": None,
    "heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "cap": None,
    "inner": "model",         # SSM d_inner
    "ssm_heads": "model",
    "state": None,
    "chunk": None,
    "frames": None,
}
ACT_RULES_LARGE = dict(ACT_RULES_SMALL)

PARAM_RULES_SMALL: dict[str, Any] = {
    "layers": None,
    "embed": "data",          # FSDP dim within a pod
    "qdim": "model",
    "kvdim": "model",
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "inner": "model",
    "state": None,
    "conv": None,
    "ssm_heads": "model",
    "head_dim": None,
    "heads": "model",
    "misc": None,
}
PARAM_RULES_LARGE = dict(PARAM_RULES_SMALL, embed=("pod", "data"))


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    act: Mapping[str, Any]
    params: Mapping[str, Any]
    log_fallbacks: bool = False

    def axis_size(self, name: str) -> int:
        return self.mesh.shape.get(name, 1)


_ACTIVE: contextvars.ContextVar[ShardingRules | None] = \
    contextvars.ContextVar("repro_sharding_rules", default=None)


def current_rules() -> ShardingRules | None:
    return _ACTIVE.get()


@contextlib.contextmanager
def use_rules(rules: ShardingRules | None):
    tok = _ACTIVE.set(rules)
    try:
        yield rules
    finally:
        _ACTIVE.reset(tok)


def _normalize(phys) -> tuple[str, ...]:
    if phys is None:
        return ()
    if isinstance(phys, str):
        return (phys,)
    return tuple(phys)


def _fit_axes(dim: int, axes: tuple[str, ...], mesh: Mesh,
              fallbacks: list[str] | None, logical: str) -> tuple[str, ...]:
    """Drop trailing physical axes until the dim divides evenly."""
    cand = list(axes)
    # only keep axes that exist in this mesh
    cand = [a for a in cand if a in mesh.shape]
    while cand:
        prod = math.prod(mesh.shape[a] for a in cand)
        if dim % prod == 0:
            return tuple(cand)
        dropped = cand.pop(0)  # drop the outermost (pod first) for locality
        if fallbacks is not None:
            fallbacks.append(f"{logical}:{dim} !% {dropped}")
    return ()


def spec_for(shape: Sequence[int], logical: Sequence[str | None],
             table: Mapping[str, Any], mesh: Mesh,
             fallbacks: list[str] | None = None) -> P:
    """PartitionSpec for a tensor given its logical axis names."""
    assert len(shape) == len(logical), (shape, logical)
    used: set[str] = set()
    parts = []
    for dim, name in zip(shape, logical):
        if name is None or name not in table:
            parts.append(None)
            continue
        axes = _fit_axes(dim, _normalize(table[name]), mesh, fallbacks, name)
        axes = tuple(a for a in axes if a not in used)
        # re-check divisibility after removing already-used axes
        if axes and dim % math.prod(mesh.shape[a] for a in axes) != 0:
            axes = ()
        used.update(axes)
        parts.append(axes if len(axes) > 1 else (axes[0] if axes else None))
    return P(*parts)


def sharding_for(shape, logical, *, params: bool = False,
                 rules: ShardingRules | None = None) -> NamedSharding | None:
    rules = rules if rules is not None else current_rules()
    if rules is None:
        return None
    table = rules.params if params else rules.act
    return NamedSharding(rules.mesh,
                         spec_for(shape, logical, table, rules.mesh))


def constraint(x: jax.Array, *logical: str | None) -> jax.Array:
    """with_sharding_constraint by logical names; identity w/o rules."""
    rules = current_rules()
    if rules is None:
        return x
    sh = sharding_for(x.shape, logical, params=False, rules=rules)
    if sh is None:
        return x
    return jax.lax.with_sharding_constraint(x, sh)


def tree_param_shardings(param_tree, logical_tree,
                         rules: ShardingRules | None = None):
    """NamedSharding pytree for params (or their ShapeDtypeStructs)."""
    rules = rules if rules is not None else current_rules()
    assert rules is not None, "tree_param_shardings needs active rules"

    def one(p, ax):
        return NamedSharding(
            rules.mesh, spec_for(p.shape, ax, rules.params, rules.mesh))

    # flatten_up_to treats the logical tree's tuples as leaves aligned with
    # the param tree's array leaves.
    return jax.tree.map(one, param_tree, logical_tree)
