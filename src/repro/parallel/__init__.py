"""Distribution substrate: sharding rules, meshes, pipeline, compression."""

from .sharding import (ShardingRules, constraint, current_rules, sharding_for,
                       spec_for, tree_param_shardings, use_rules)

__all__ = ["ShardingRules", "constraint", "current_rules", "sharding_for",
           "spec_for", "tree_param_shardings", "use_rules"]
from .pipeline import bubble_fraction, gpipe_schedule, pipeline_apply  # noqa
