"""Distribution substrate: jax-version compat seam, sharding rules,
meshes, pipeline, compression."""

from .compat import psum_scalar, pvary, shard_map
from .sharding import (ShardingRules, constraint, current_rules, sharding_for,
                       spec_for, tree_param_shardings, use_rules)

__all__ = ["shard_map", "pvary", "psum_scalar",
           "ShardingRules", "constraint", "current_rules", "sharding_for",
           "spec_for", "tree_param_shardings", "use_rules"]
from .pipeline import bubble_fraction, gpipe_schedule, pipeline_apply  # noqa
