"""Gradient compression for cross-pod data parallelism.

At 512+ chips the DP all-reduce of 100B-class gradients dominates the
inter-pod (DCN) link; two standard mitigations, both implemented as pure
pytree transforms so they compose with any optimizer:

* int8 quantized all-reduce — per-tensor absmax scaling, ~4× fewer bytes
  on the wire; psum of int32-accumulated int8 values.
* top-k sparsification with error feedback (memory) — keeps the k largest
  entries per tensor, residual is fed back next step (1-bit Adam-style
  convergence behaviour).

These run inside ``shard_map`` over the DP axes; under plain ``jit`` the
quantize/dequantize still executes (useful for numerics tests) and the
psum is a no-op identity.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from .compat import axis_size, psum_scalar, pvary

__all__ = ["quantize_int8", "dequantize_int8", "psum_int8",
           "topk_with_error_feedback", "init_error_feedback"]


def quantize_int8(x: jax.Array):
    """Per-tensor symmetric absmax int8 quantization -> (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def psum_int8(grads, axis_names: Sequence[str]):
    """Quantized DP all-reduce: quantize → psum(int32) → dequantize(mean).

    Must run inside shard_map with ``axis_names`` bound.  Scales are
    averaged across replicas (each replica's absmax differs slightly).
    """
    def one(g):
        q, s = quantize_int8(g)
        acc = psum_scalar(q.astype(jnp.int32), axis_names)
        s = pvary(s, axis_names)
        n = 1
        for ax in axis_names:
            s = jax.lax.pmean(s, ax)
            n = n * axis_size(ax)
        return (acc.astype(jnp.float32) * s / n).astype(g.dtype)

    return jax.tree.map(one, grads)


def init_error_feedback(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def topk_with_error_feedback(grads, memory, frac: float = 0.01):
    """Keep the top-``frac`` magnitude entries per tensor; the rest is
    accumulated into ``memory`` and re-added next step.

    Returns (sparse_grads, new_memory)."""
    def one(g, m):
        gf = g.astype(jnp.float32) + m
        flat = jnp.abs(gf).reshape(-1)
        k = max(1, int(frac * flat.size))
        thresh = jax.lax.top_k(flat, k)[0][-1]
        keep = jnp.abs(gf) >= thresh
        sparse = jnp.where(keep, gf, 0.0)
        return sparse.astype(g.dtype), gf - sparse

    flat, tdef = jax.tree.flatten(grads)
    mem = tdef.flatten_up_to(memory)
    out = [one(g, m) for g, m in zip(flat, mem)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))
