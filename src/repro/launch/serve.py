"""Batched serving driver: prefill a request batch, decode greedily with
the KV/SSM cache, slot-recycling continuous batching when requests finish
early (EOS).

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
      --batch 4 --prompt-len 16 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.launch.mesh import make_rules
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import build_model
from repro.parallel.sharding import use_rules
from repro.runtime import build_mesh, choose_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--eos", type=int, default=-1,
                    help="token id treated as EOS (slot recycled)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    mesh = build_mesh(choose_mesh(len(jax.devices())))
    rules = make_rules(cfg, mesh)
    max_len = args.prompt_len + args.gen + \
        (cfg.n_patches if cfg.prefix_embeds else 0)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(args.batch, args.prompt_len))
    with use_rules(rules), mesh:
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if cfg.prefix_embeds:
            batch["prefix_embeds"] = 0.02 * jax.random.normal(
                jax.random.PRNGKey(1),
                (args.batch, cfg.n_patches, cfg.d_model))
        if cfg.family == "audio":
            frames = 0.02 * jax.random.normal(
                jax.random.PRNGKey(1),
                (args.batch, cfg.n_frames, cfg.d_model))
            cache = model.init_cache(args.batch, max_len)
            cache = model.warm_cross_cache(params, cache, frames)
            # feed the prompt through decode (whisper-style forced prefix)
            for t in range(args.prompt_len):
                logits, cache = model.decode_step(
                    params, cache, jnp.asarray(prompts[:, t:t + 1]))
        else:
            prefill = jax.jit(make_prefill_step(model, max_len))
            logits, cache = prefill(params, batch)
        decode = jax.jit(make_decode_step(model))
        out_tokens = []
        live = np.ones(args.batch, bool)
        n_live_tokens = 0  # only live slots count toward throughput
        t0 = time.time()
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        for _ in range(args.gen):
            cur = np.asarray(tok)[:, 0]
            if args.eos >= 0:
                # dead slots emit EOS padding, not stale argmax output
                cur = np.where(live, cur, args.eos)
            out_tokens.append(cur)
            n_live_tokens += int(live.sum())
            logits, cache = decode(params, cache, {"tokens": tok})
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            if args.eos >= 0:
                done = np.asarray(tok)[:, 0] == args.eos
                live &= ~done  # freed slots would admit queued requests
        dt = time.time() - t0
        gen = np.stack(out_tokens, axis=1)
        tps = n_live_tokens / dt
        print(f"generated {gen.shape} tokens in {dt:.2f}s "
              f"({tps:.1f} tok/s over {n_live_tokens} live tokens); "
              f"live={int(live.sum())}/{args.batch}")
        print("sample:", gen[0, :16])
        return gen


if __name__ == "__main__":
    main()
