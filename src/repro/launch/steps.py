"""Step factories shared by train/serve drivers, the dry-run and tests."""

from __future__ import annotations

from typing import Any, Callable

import jax

from repro.models import build_model
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step",
           "init_train_state"]


def init_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig, key):
    model = build_model(cfg)
    params = model.init(key)
    opt_state = adamw_init(params, opt_cfg)
    return model, params, opt_state


def make_train_step(model, opt_cfg: AdamWConfig) -> Callable:
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state, metrics = adamw_update(params, grads, opt_state,
                                                  opt_cfg)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics
    return train_step


def make_prefill_step(model, max_len: int) -> Callable:
    cfg = model.cfg

    def prefill_step(params, batch):
        if cfg.family == "audio":
            # enc-dec "prefill" = teacher-forced decoder pass over the
            # prompt + encoder memory (cache build happens in decode)
            logits, _ = model.forward(params, batch["tokens"],
                                      batch["frame_embeds"])
            return logits[:, -1]
        return model.prefill(params, batch["tokens"], max_len=max_len,
                             prefix_embeds=batch.get("prefix_embeds"))
    return prefill_step


def make_decode_step(model) -> Callable:
    def decode_step(params, cache, batch):
        return model.decode_step(params, cache, batch["tokens"])
    return decode_step
