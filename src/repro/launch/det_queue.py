"""Async pipelined determinant serving: a thread-safe request/response
queue over the shape-bucketed batched Radic evaluator.

The synchronous ``det_serve.drain_queue`` reference interleaves three
host/device phases per batch — *stage* (pad + stack + upload), *dispatch*
(enter the jitted program) and *complete* (block + unpack + deliver) —
so the device idles while the host pads batch k+1 and the host idles
while the device computes batch k.  This module splits the phases onto a
three-thread pipeline connected by bounded queues:

    submit() ──► pending ──[stager]──► inflight ──[completer]──► futures

* **stager** snapshots the pending requests, plans buckets (below),
  pads each group into a host stack, starts the upload with
  ``jax.device_put`` and enters the AOT-compiled executable *without
  blocking*: jax dispatch is asynchronous on every backend, so the call
  only enqueues device work and the thread immediately stages batch
  k+1 behind the executing batch k.
* **completer** blocks on the oldest in-flight result, unpacks it and
  resolves the per-request futures (and the ``poll()`` response queue).

Staging and dispatch share one thread on purpose: dispatch through a
compiled executable is ~50 µs of python, far too little to earn a third
thread's context-switch traffic on small hosts; the bounded ``inflight``
queue alone provides the device-side backpressure.

Re-bucketing is dynamic (:class:`BucketPolicy`): under load, under-filled
buckets that share a row count ``m`` are **merged** by zero-padding
columns up to a canonical width — exact for the Radic determinant, since
every minor that touches a zero column vanishes — so many single-request
compiles/dispatches collapse into one; hot buckets are **split** into
``max_batch`` slices that overlap each other in the pipeline.  Batch
composition never changes a result: padding rows/neighbors are sliced
off before delivery and the per-element math is independent, so results
stay bit-identical to a single-threaded
:func:`repro.core.radic_det_batched` call at the same canonical shape
(``tests/test_det_queue.py`` pins this down).

The dispatcher holds :class:`repro.core.engine.DetPlan` s, not raw
lambdas: every executable (AOT-lowered jnp, pallas, mesh) lives in one
:class:`repro.core.engine.DetEngine` with an LRU-bounded cache (see
DESIGN_ENGINE.md), and admission control (``max_pending`` +
:class:`LoadShedError`) bounds the backlog under overload.

Mesh evaluation stays routed through ``repro.core.distributed`` (and
thus ``repro.parallel.compat``) — this module never touches collectives
directly.
"""

from __future__ import annotations

import queue
import threading
import time
from collections import defaultdict, deque
from concurrent.futures import Future
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core import DetEngine, comb

__all__ = ["BucketPolicy", "DetQueue", "LoadShedError", "QueueClosedError",
           "Request", "StagePlan", "plan_buckets", "pad_capacity",
           "bucket_by_shape", "drain_responses", "prepare_matrix",
           "resolve_future"]


def resolve_future(fut: Future, val=None, exc: BaseException | None = None):
    """set_result/set_exception tolerating a racing cancel: a future
    cancelled between the done() check and the set would otherwise raise
    InvalidStateError and take a pipeline thread down.  Shared by the
    queue and the multi-worker front."""
    try:
        if fut.done():
            return
        if exc is not None:
            fut.set_exception(exc)
        else:
            fut.set_result(val)
    except Exception:  # noqa: BLE001 — InvalidStateError from cancel race
        pass


def prepare_matrix(A, dtype) -> np.ndarray:
    """Host-side request validation shared by queue and front: a single
    2-D matrix at the serving dtype."""
    arr = np.asarray(A, dtype=dtype)
    if arr.ndim != 2:
        raise ValueError(f"request is not a matrix: shape {arr.shape}")
    return arr


def drain_responses(responses: deque, cv: threading.Condition,
                    eos, max_items: int | None,
                    timeout: float | None) -> list[tuple]:
    """The shared ``poll()`` drain loop behind DetQueue and DetFront.

    Waits up to ``timeout`` for the first response (``0`` → pure poll,
    ``None`` → wait indefinitely), then drains whatever else is ready,
    up to ``max_items``.  ``eos()`` is the caller's end-of-stream
    predicate, evaluated under ``cv`` — true only once no response can
    ever be produced again (the two callers genuinely differ here:
    the queue's pipeline threads vs the front's drainer flag).
    """
    deadline = None if timeout is None else time.monotonic() + timeout
    out: list[tuple] = []
    while max_items is None or len(out) < max_items:
        try:
            out.append(responses.popleft())
            continue
        except IndexError:
            pass
        if out:
            break
        with cv:
            if responses:
                continue
            if eos():
                break
            if deadline is None:
                cv.wait()
            else:
                remaining = deadline - time.monotonic()
                if remaining <= 0 or not cv.wait(remaining):
                    break
    return out


class QueueClosedError(RuntimeError):
    """Raised on a pending request's future when the queue shuts down
    without serving it (``close(drain=False)``, or a teardown path that
    abandons the backlog).

    A serving front tearing a worker down must be able to call
    ``close()`` with a non-empty backlog and have every pending future
    resolve with *this* — never hang, never silently cancel — so the
    caller can distinguish "the queue went away" from a result, a
    :class:`LoadShedError`, or a per-batch evaluation error and re-route
    the request elsewhere.
    """


class LoadShedError(RuntimeError):
    """Raised on a request's future when admission control sheds it.

    A bounded backlog (``DetQueue(max_pending=...)``) protects the
    pipeline from unbounded memory growth and unbounded tail latency
    under overload: once the pending backlog is full, new submissions
    are rejected *immediately* — the future carries this exception and
    the ``poll()`` stream still delivers the request's seq exactly once
    — instead of queueing behind work that can't be served at the
    arrival rate (see ``benchmarks/perf_serve.py --arrival poisson``).
    """


def bucket_by_shape(mats) -> dict[tuple[int, int], list[int]]:
    """Queue indices grouped by exact (m, n) shape, shapes sorted."""
    buckets: dict[tuple[int, int], list[int]] = defaultdict(list)
    for i, A in enumerate(mats):
        shp = np.shape(A)
        if len(shp) != 2:
            raise ValueError(f"request {i} is not a matrix: shape {shp}")
        buckets[tuple(shp)].append(i)
    return dict(sorted(buckets.items()))


def pad_capacity(k: int, max_batch: int) -> int:
    """Smallest power of two >= k, capped at ``max_batch``.

    ``k == 0`` (an empty bucket) has capacity 0: empty buckets dispatch
    nothing — a phantom all-zero row is wasted device work and a wasted
    jit cache entry.
    """
    if k <= 0:
        return 0
    cap = 1
    while cap < min(k, max_batch):
        cap *= 2
    return min(cap, max_batch)


@dataclass(frozen=True)
class BucketPolicy:
    """Dynamic re-bucketing knobs (all decisions are pure functions).

    mode:
      * ``"auto"`` — merge under-filled buckets only under load;
      * ``"merge"`` — always merge to the canonical column class
        (deterministic shapes regardless of load — what the bit-identity
        tests force);
      * ``"never"`` — exact-shape buckets only.

    A bucket with fewer than ``merge_below`` pending requests merges
    when the drained queue depth is at least ``merge_depth`` (``auto``).
    Merging rounds ``n`` up to the next multiple of ``col_class`` (never
    past ``col_max``); only buckets sharing ``m`` can land in the same
    canonical bucket.  The extra C(n_canon, m) − C(n, m) ranks all hit a
    zero column, so they contribute exact zeros.

    A bucket deeper than ``max_batch`` is split into ``max_batch``
    slices — under light load a bucket drains as one small padded batch,
    while a hot bucket fans out into several slices that overlap each
    other in the pipeline.  ``pin_capacity`` pads *every* batch to
    ``max_batch`` instead of the per-group power of two: one program
    shape per bucket, and per-request results that are independent of
    how requests happened to be grouped (XLA specializes per batch
    shape, so varying capacities can flip low-order bits — see
    DESIGN_SERVE.md; the bit-identity tests pin capacity for exactly
    this reason).
    """

    max_batch: int = 64
    mode: str = "auto"
    merge_below: int = 4
    merge_depth: int = 32
    col_class: int = 4
    col_max: int = 16
    pin_capacity: bool = False
    exact_capacity: bool = True

    def __post_init__(self):
        if self.mode not in ("auto", "merge", "never"):
            raise ValueError(f"unknown policy mode {self.mode!r}")
        if self.max_batch < 1 or self.col_class < 1:
            raise ValueError("max_batch and col_class must be >= 1")

    def to_wire(self) -> dict:
        """Plain-dict wire form for the socket transport's handshake:
        the daemon rebuilds the policy from the front's dict, so both
        sides bucket identically by construction (pickling the class
        would silently bind the daemon to the front's code version)."""
        from dataclasses import asdict
        return asdict(self)

    @classmethod
    def from_wire(cls, d: dict) -> "BucketPolicy":
        from dataclasses import fields
        names = {f.name for f in fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})

    def canonical_shape(self, m: int, n: int) -> tuple[int, int]:
        """Merge target: n rounded up to the next ``col_class`` multiple."""
        if m > n or n >= self.col_max:
            return (m, n)  # zero-by-definition and huge shapes never merge
        n_canon = min(-(-n // self.col_class) * self.col_class, self.col_max)
        return (m, max(n_canon, n))

    def should_merge(self, pending: int, depth: int) -> bool:
        if self.mode == "merge":
            return True
        if self.mode == "never":
            return False
        return pending < self.merge_below and depth >= self.merge_depth

    def capacity(self, group: int) -> int:
        if group <= 0:
            return 0
        if self.pin_capacity:
            return self.max_batch
        if self.exact_capacity:
            # no padded batch rows at all: the AOT executable cache makes
            # one program per (shape, exact size) affordable, unlike the
            # traced path whose jit cache wants the pow2 bound (at most
            # max_batch variants per shape either way)
            return min(group, self.max_batch)
        return pad_capacity(group, self.max_batch)


@dataclass
class Request:
    """One queued matrix plus its delivery endpoints.

    ``grad=True`` requests the cofactor-form VJP instead of the value:
    the result is the ``(m, n)`` gradient array ``ct · ∂det/∂A``
    (DESIGN_GRAD.md).  ``ct`` is the scalar cotangent — the determinant
    is scalar-valued, so the full cotangent payload is one float, which
    is what keeps the wire descriptor plain-typed.
    """
    seq: int
    array: np.ndarray          # host copy, already the serving dtype
    shape: tuple[int, int]
    future: Future = field(default_factory=Future)
    t_submit: float = field(default_factory=time.perf_counter)
    grad: bool = False
    ct: float = 1.0


@dataclass
class StagePlan:
    """One device batch: requests bound to a canonical shape + capacity."""
    shape: tuple[int, int]     # canonical (m, n) the stack is padded to
    requests: list[Request]
    capacity: int
    merged_count: int          # how many requests were column-padded here
    grad: bool = False         # gradient batch: dispatches plan.grad

    @property
    def merged(self) -> bool:
        return self.merged_count > 0


def plan_buckets(requests: list[Request], policy: BucketPolicy,
                 depth: int | None = None) -> list[StagePlan]:
    """Pure bucket planner: requests → list of device batches.

    Groups by exact (shape, grad), applies the merge policy to pick each
    bucket's canonical shape, coalesces same-target buckets (FIFO by
    submit ``seq``), then splits every target bucket into
    ``<= max_batch`` slices with the policy's capacity.  Empty input
    plans nothing.

    Gradient buckets never column-merge: zero-padded columns are exact
    for the *value* (every minor touching one vanishes) but the result
    of a grad request is the full ``(m, n)`` array, whose shape the
    caller asked for — and ``jnp.linalg.det``'s pullback can be
    non-finite on rank-deficient padding.  Values and gradients of the
    same shape stay in separate device batches (one dispatches the
    forward executable, the other the VJP program).
    """
    if depth is None:
        depth = len(requests)
    by_shape: dict[tuple[tuple[int, int], bool], list[Request]] = \
        defaultdict(list)
    for r in requests:
        by_shape[(r.shape, r.grad)].append(r)
    targets: dict[tuple[tuple[int, int], bool], list[Request]] = \
        defaultdict(list)
    for (shape, grad), reqs in sorted(by_shape.items()):
        if not grad and policy.should_merge(len(reqs), depth):
            target = policy.canonical_shape(*shape)
        else:
            target = shape
        targets[(target, grad)].extend(reqs)
    plans: list[StagePlan] = []
    for (target, grad), reqs in sorted(targets.items()):
        reqs.sort(key=lambda r: r.seq)
        for base in range(0, len(reqs), policy.max_batch):
            grp = reqs[base:base + policy.max_batch]
            plans.append(StagePlan(
                shape=target, requests=grp,
                capacity=policy.capacity(len(grp)),
                merged_count=sum(1 for r in grp if r.shape != target),
                grad=grad))
    return plans


class _Shutdown:
    """Sentinel flowing through the pipeline queues."""


_SHUTDOWN = _Shutdown()


class DetQueue:
    """Thread-safe submit/poll determinant server with a staged pipeline.

    >>> with DetQueue(max_batch=32) as q:
    ...     fut = q.submit(np.ones((2, 5), np.float32))
    ...     det = fut.result(timeout=30)

    ``submit`` never blocks on device work; results arrive through the
    returned future and, tagged with the request sequence number, through
    ``poll()``.  ``serve(mats)`` is the synchronous convenience wrapper
    (submit all, wait all) used by the CLI and benchmarks.
    """

    # reprolint lock-discipline registry (see DESIGN_LINT.md): these
    # attributes are shared between the caller, the stager and the
    # completer and may only be touched under one of the listed locks.
    # ``_wake`` is a Condition sharing ``_lock``, so holding either names
    # the same mutex; ``_responses`` lives under the response cv.
    _GUARDED_BY = {
        "_pending": ("_lock", "_wake"),
        "_seq": ("_lock", "_wake"),
        "_closing": ("_lock", "_wake"),
        "_fatal": ("_lock", "_wake"),
        "stats": ("_lock", "_wake"),
        "_responses": ("_resp_cv",),
    }

    def __init__(self, *, chunk: int = 2048, backend: str = "jnp",
                 max_batch: int | None = None,
                 policy: BucketPolicy | None = None,
                 dtype=np.float32, mesh=None, batch_axis: str | None = None,
                 pipeline_depth: int = 8, linger_s: float = 0.0,
                 stage_depth: int | None = None,
                 response_buffer: int = 65536,
                 max_pending: int | None = None,
                 engine: DetEngine | None = None, plan_cache: int = 128,
                 persist_dir: str | None = None):
        if policy is None:
            policy = BucketPolicy(
                max_batch=64 if max_batch is None else max_batch)
        elif max_batch is not None and max_batch != policy.max_batch:
            raise ValueError(
                f"conflicting max_batch: argument {max_batch} vs "
                f"policy.max_batch {policy.max_batch} — set it on the "
                "policy only")
        if max_pending is not None and max_pending < 1:
            raise ValueError("max_pending must be >= 1 (or None)")
        self.policy = policy
        self.chunk = chunk
        self.backend = backend
        self.dtype = np.dtype(dtype)
        self.mesh = mesh
        self.batch_axis = batch_axis
        self.linger_s = linger_s
        # the linger gate: how deep a pending snapshot must be before the
        # stager stops waiting for more arrivals.  The default (one
        # max_batch) is right for single-hot-bucket traffic, but a
        # multi-bucket stream spreads a snapshot over many shapes — with
        # pinned capacities every thin per-bucket group then pays a full
        # batch of padded device work, so serving tiers with B hot
        # buckets want roughly B * max_batch here (see
        # benchmarks/perf_serve.py --workers).
        self.stage_depth = policy.max_batch if stage_depth is None \
            else int(stage_depth)
        self.max_pending = max_pending
        # the dispatcher holds DetPlans, not raw lambdas: the engine owns
        # every executable behind one LRU-bounded cache (long-tail shape
        # traffic can no longer grow the executable map without limit)
        # ``persist_dir`` turns on the durable plan store
        # (DESIGN_PERSIST.md): misses consult it before compiling and
        # fresh plans write back in the store's background thread.
        self.engine = engine if engine is not None \
            else DetEngine(max_plans=plan_cache, persist_dir=persist_dir)

        self._lock = threading.Lock()
        self._wake = threading.Condition(self._lock)
        self._pending: list[Request] = []
        self._seq = 0
        self._closing = False
        self._fatal: BaseException | None = None

        self._inflight: queue.Queue = queue.Queue(maxsize=pipeline_depth)
        # bounded: futures-only consumers never poll, so an unbounded
        # response log would leak on a long-lived queue.  Overflow drops
        # the oldest responses and is counted in stats.
        self._responses: deque = deque(maxlen=response_buffer)
        self._resp_cv = threading.Condition()

        self.stats = self._zero_stats()

        self._threads = [
            threading.Thread(target=self._stager, name="det-stager",
                             daemon=True),
            threading.Thread(target=self._completer, name="det-completer",
                             daemon=True),
        ]
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------- submit
    def _enqueue(self, arrs: list[np.ndarray],
                 grads: list[tuple[bool, float]] | None = None
                 ) -> list[Future]:
        """Append prepared arrays under one lock, with one stager wake.

        ``grads`` pairs each array with its ``(grad, cotangent)``
        request mode (None → all value requests).

        Admission control: with ``max_pending`` set, arrays that would
        grow the un-staged backlog past the bound are *shed* — their
        future resolves immediately with :class:`LoadShedError` and
        their seq flows through ``poll()`` like any other response (so
        poll-driven consumers see every submission exactly once).  The
        check runs under the same lock the stager snapshots under, so a
        single ``submit_many`` burst sheds deterministically.
        """
        if grads is None:
            grads = [(False, 1.0)] * len(arrs)
        elif len(grads) != len(arrs):
            raise ValueError(
                f"grads length {len(grads)} != matrices {len(arrs)}")
        futs: list[Future] = []
        shed: list[Request] = []
        with self._wake:
            if self._closing:
                raise QueueClosedError("DetQueue is closed")
            if self._fatal is not None:
                raise RuntimeError("DetQueue pipeline died") from self._fatal
            for arr, (grad, ct) in zip(arrs, grads):
                req = Request(seq=self._seq, array=arr,
                              shape=(arr.shape[0], arr.shape[1]),
                              grad=bool(grad), ct=float(ct))
                self._seq += 1
                req.future.seq = req.seq
                futs.append(req.future)
                self.stats["submitted"] += 1
                if self.max_pending is not None \
                        and len(self._pending) >= self.max_pending:
                    self.stats["shed"] += 1
                    shed.append(req)
                    continue
                self._pending.append(req)
                self.stats["backlog_peak"] = max(
                    self.stats["backlog_peak"], len(self._pending))
            self._wake.notify_all()
        for req in shed:
            exc = LoadShedError(
                f"backlog full ({self.max_pending} pending): request "
                f"seq={req.seq} shape={req.shape} shed")
            with self._resp_cv:
                # same drop accounting as _deliver: an append into a full
                # response deque evicts the oldest undrained response
                dropped = max(0, len(self._responses) + 1
                              - (self._responses.maxlen or 0))
                self._responses.append((req.seq, exc))
                self._resp_cv.notify_all()
            if dropped:
                with self._lock:
                    self.stats["responses_dropped"] += dropped
            self._resolve(req.future, exc=exc)
        return futs

    def _prepare(self, A) -> np.ndarray:
        return prepare_matrix(A, self.dtype)

    def submit(self, A, *, grad: bool = False,
               cotangent: float = 1.0) -> Future:
        """Enqueue one matrix; returns a ``Future`` carrying ``.seq``.
        With ``grad=True`` the future resolves to the ``(m, n)`` array
        ``cotangent · ∂det/∂A`` instead of the determinant value."""
        return self._enqueue([self._prepare(A)],
                             [(grad, cotangent)])[0]

    def submit_many(self, mats, grads=None) -> list[Future]:
        """Enqueue a burst atomically: the stager sees one deep snapshot
        (full batches, load-aware re-bucketing) instead of a trickle.
        ``grads`` optionally pairs each matrix with ``(grad, cotangent)``
        (see :meth:`submit`)."""
        return self._enqueue([self._prepare(A) for A in mats], grads)

    def poll(self, max_items: int | None = None,
             timeout: float | None = 0.0) -> list[tuple[int, float]]:
        """Drain completed ``(seq, det)`` responses.

        Waits up to ``timeout`` for the first response (``0`` → pure
        poll, ``None`` → wait indefinitely), then drains whatever else is
        ready, up to ``max_items``.  A failed request's response carries
        the exception instance instead of a float — every submitted seq
        eventually appears exactly once.
        """
        # end-of-stream only once the pipeline has actually finished:
        # close(drain=True) keeps delivering responses after _closing is
        # set, and close() re-notifies the cv when the threads have been
        # joined
        def eos():
            with self._lock:
                closing, fatal = self._closing, self._fatal
            return (closing
                    and not any(t.is_alive() for t in self._threads)) \
                or fatal is not None
        # the deque reference is immutable after __init__; drain_responses
        # does every mutation under the cv it is handed here
        return drain_responses(self._responses, self._resp_cv, eos,  # reprolint: disable=lock-discipline
                               max_items, timeout)

    def serve(self, mats, timeout: float | None = None):
        """Submit everything, wait for everything; ``(dets, stats)``.

        Consumes the ``poll()`` responses of its own requests (don't mix
        ``serve`` with a concurrent ``poll`` consumer on one queue).
        """
        futs = self.submit_many(mats)
        dets = [f.result(timeout=timeout) for f in futs]
        self.poll(timeout=0)
        return dets, self.snapshot()

    @staticmethod
    def _zero_stats() -> dict:
        return {
            "submitted": 0, "completed": 0, "batches": 0, "dispatches": 0,
            "merged_requests": 0, "padded_slots": 0, "ranks": 0,
            "responses_dropped": 0, "shed": 0, "backlog_peak": 0,
            "stage_s": 0.0, "complete_s": 0.0,
            "buckets": {},
        }

    def snapshot(self) -> dict:
        with self._lock:
            s = dict(self.stats)
            s["buckets"] = {k: dict(v) for k, v in self.stats["buckets"].items()}
        s["plan_cache"] = self.engine.cache_info()
        return s

    def reset_stats(self):
        """Zero the counters (benchmarks: after the warm/compile pass, so
        a snapshot covers only the steady-state serving that followed)."""
        with self._lock:
            self.stats = self._zero_stats()

    # -------------------------------------------------------------- close
    def drain_pending(self) -> list[Request]:
        """Atomically remove and return every not-yet-staged request.

        The re-routing hook for a serving front: the caller takes
        ownership of the returned :class:`Request` s — their futures are
        still unresolved, their seqs have not appeared on the ``poll()``
        stream — and is responsible for either resolving them or
        re-submitting the arrays elsewhere (``launch/det_front.py`` does
        the latter when it retires a worker).  Requests already staged
        into the pipeline are not touched; they complete normally.
        """
        with self._wake:
            pend, self._pending = self._pending, []
        return pend

    def close(self, drain: bool = True, timeout: float | None = None):
        """Shut the pipeline down.  Idempotent and safe with a non-empty
        backlog: ``drain=True`` (default) serves everything already
        submitted; ``drain=False`` abandons the un-staged backlog, but
        every abandoned future resolves with :class:`QueueClosedError`
        (and its seq still flows through ``poll()``) — pending work never
        hangs a caller, whichever teardown path ran first.  Every call
        joins the pipeline threads, so concurrent/repeated ``close()``
        calls all return only once the pipeline has actually stopped.
        """
        with self._wake:
            self._closing = True
            pend: list[Request] = []
            if not drain:
                pend, self._pending = self._pending, []
            self._wake.notify_all()
        if pend:
            exc = QueueClosedError(
                f"DetQueue closed with {len(pend)} un-staged requests")
            with self._resp_cv:
                self._responses.extend((r.seq, exc) for r in pend)
                self._resp_cv.notify_all()
            for r in pend:
                self._resolve(r.future, exc=exc)
        for t in self._threads:
            t.join(timeout=timeout)
        with self._resp_cv:  # wake any poller blocked on a closed queue
            self._resp_cv.notify_all()
        # plan persistence is write-behind (DESIGN_PERSIST.md): drain the
        # store's writer so a short-lived process still lands its plans
        self.engine.flush_store()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ----------------------------------------------------------- pipeline
    def _plan(self, shape: tuple[int, int], capacity: int):
        """The :class:`~repro.core.engine.DetPlan` for one device batch.

        The engine owns the executables: AOT-lowered per (shape,
        capacity) on the jnp single-device path (the *same* jitted
        program the one-shot path traces — bit-identical results — with
        the per-dispatch python paid once, off the dispatcher's hot
        loop; the engine falls back to the traced program internally if
        lowering fails), traced programs for pallas/mesh.  The cache is
        LRU-bounded, so a long tail of request shapes re-plans instead
        of growing without limit.
        """
        m, n = shape
        aot = self.backend == "jnp" and self.mesh is None
        return self.engine.plan(
            m, n, batched=True, capacity=capacity if aot else None,
            dtype=self.dtype, chunk=self.chunk, backend=self.backend,
            mesh=self.mesh, batch_axis=self.batch_axis)

    def prefill(self, entries) -> int:
        """Warm the engine for expected plan families before traffic.

        ``entries``: iterable of ``(m, n, capacity)`` — the wire form of
        a join handshake's prefill list (capacity is the policy bound;
        dtype/backend/chunk come from this queue's own config, exactly
        as ``_plan`` would bind them, so a prefetched plan IS the plan
        the first real batch will hit).  With a plan store configured
        the warm path is store-first, compile-second.  Malformed or
        unplannable entries are skipped; returns the number warmed.
        """
        warmed = 0
        for e in entries:
            try:
                m, n, cap = int(e[0]), int(e[1]), e[2]
                cap = None if cap is None else int(cap)
            except (TypeError, ValueError, IndexError):
                continue
            try:
                self._plan((m, n), cap)
                warmed += 1
            except Exception:   # noqa: BLE001 — prefill is best-effort
                continue
        return warmed

    _resolve = staticmethod(resolve_future)

    def _fail_plan(self, plan: StagePlan, exc: BaseException):
        """Fail one batch; the pipeline keeps serving others.

        The error is delivered on both response paths: the futures get
        ``set_exception``, and ``poll()`` consumers get a ``(seq, exc)``
        tuple — otherwise a poll-driven consumer would wait forever for
        an errored request's seq.
        """
        with self._resp_cv:
            self._responses.extend((r.seq, exc) for r in plan.requests)
            self._resp_cv.notify_all()
        for r in plan.requests:
            self._resolve(r.future, exc=exc)

    def _fatal_now(self) -> BaseException | None:
        """The pipeline-death exception, read under the lock (None while
        healthy).  ``_fatal`` is never reset, so a non-None result is
        stable without holding the lock further."""
        with self._lock:
            return self._fatal

    def _put_alive(self, q_: queue.Queue, item) -> bool:
        """Bounded put that aborts if the pipeline died.

        A dead downstream thread stops consuming; blocking forever in
        ``put()`` would then hang ``close()``.  Returns False once
        ``_fatal`` is set — the caller fails its in-hand batch and exits.
        """
        while self._fatal_now() is None:
            try:
                q_.put(item, timeout=0.2)
                if self._fatal_now() is not None:
                    # raced a dying pipeline: nobody may consume this item
                    self._drain_failed()
                return True
            except queue.Full:
                continue
        return False

    def _drain_failed(self):
        """Fail every batch sitting in the pipeline queue (fatal path)."""
        exc = self._fatal_now()
        while True:
            try:
                item = self._inflight.get_nowait()
            except queue.Empty:
                break
            if isinstance(item, tuple):
                for r in item[0].requests:
                    self._resolve(r.future, exc=exc)

    def _fail_all(self, exc: BaseException):
        """A pipeline thread died: fail every future still in the system
        and unstick the sibling threads so ``close()`` can join them."""
        with self._wake:
            self._fatal = exc
            pend, self._pending = self._pending, []
            self._wake.notify_all()  # stager waits on this; it exits on fatal
        for r in pend:
            self._resolve(r.future, exc=exc)
        self._drain_failed()
        try:  # just drained, so there is room; a racing refill is
            self._inflight.put_nowait(_SHUTDOWN)  # handled by _put_alive
        except queue.Full:
            pass
        with self._resp_cv:
            self._resp_cv.notify_all()

    def _deliver(self, plan: StagePlan, outs: list[float], *, ranks: int = 0,
                 complete_s: float = 0.0, count_batch: bool = False):
        """Deliver one finished batch — ``poll()`` responses and stats
        strictly before the futures resolve: a caller woken by the
        batch's last future must observe the batch fully counted and its
        responses visible (``serve()`` and the stats assertions in the
        tests rely on this).  ``count_batch`` is for paths that bypass
        the stager's batch accounting (the trivial m > n short-circuit).
        """
        k = len(plan.requests)
        now = time.perf_counter()
        wait = sum(now - r.t_submit for r in plan.requests)
        # drop accounting under the response cv so concurrent deliverers
        # (stager's trivial path + completer) don't both read a stale
        # length; an active poller draining in parallel can still make
        # this an upper bound, which is fine for a diagnostic counter
        with self._resp_cv:
            dropped = max(0, len(self._responses) + k
                          - (self._responses.maxlen or 0))
            self._responses.extend(
                (r.seq, val) for r, val in zip(plan.requests, outs))
            self._resp_cv.notify_all()
        with self._lock:
            st = self.stats
            st["batches"] += 1 if count_batch else 0
            st["completed"] += k
            st["ranks"] += ranks
            st["complete_s"] += complete_s
            st["responses_dropped"] += dropped
            b = st["buckets"].setdefault(
                plan.shape, {"count": 0, "batches": 0, "ranks": 0,
                             "wait_s": 0.0})
            b["count"] += k
            b["batches"] += 1
            b["ranks"] += ranks
            b["wait_s"] += wait
        for r, val in zip(plan.requests, outs):
            self._resolve(r.future, val)

    def _complete_trivial(self, plan: StagePlan):
        """Deliver an m > n batch (det = 0 by definition) straight from
        the stager: no device work at all.  A grad request's pullback is
        the all-zero ``(m, n)`` array for the same reason."""
        if plan.grad:
            m, n = plan.shape
            outs = [np.zeros((m, n), dtype=self.dtype)
                    for _ in plan.requests]
        else:
            outs = [0.0] * len(plan.requests)
        self._deliver(plan, outs, count_batch=True)

    def _stage_one(self, plan: StagePlan):
        """Pad + stack + begin the async upload for one planned batch.

        Grad batches also stage the per-matrix cotangent vector; padded
        slots carry ``ct = 0`` and are sliced off before delivery, so
        whatever the pullback produces for the all-zero padding matrices
        never reaches a caller.
        """
        m, n = plan.shape
        stack = np.zeros((plan.capacity, m, n), dtype=self.dtype)
        for j, r in enumerate(plan.requests):
            rm, rn = r.shape
            stack[j, :rm, :rn] = r.array   # zero col-pad is det-exact
        dev = jax.device_put(stack)
        if not plan.grad:
            return dev, None
        cts = np.zeros((plan.capacity,), dtype=self.dtype)
        for j, r in enumerate(plan.requests):
            cts[j] = r.ct
        return dev, jax.device_put(cts)

    def _stager(self):
        try:
            while True:
                with self._wake:
                    while not self._pending and not self._closing \
                            and self._fatal is None:
                        self._wake.wait()
                    if self._fatal is not None:
                        return
                    if self.linger_s > 0 and not self._closing and \
                            len(self._pending) < self.stage_depth:
                        # a deadline loop, not a single wait: every submit
                        # notifies _wake, and a trickle of early wakes
                        # must not cut the batching window short
                        deadline = time.monotonic() + self.linger_s
                        while not self._closing and self._fatal is None \
                                and len(self._pending) < self.stage_depth:
                            remaining = deadline - time.monotonic()
                            if remaining <= 0:
                                break
                            self._wake.wait(remaining)
                    reqs, self._pending = self._pending, []
                    closing = self._closing
                if reqs:
                    t0 = time.perf_counter()
                    depth = len(reqs)
                    for plan in plan_buckets(reqs, self.policy, depth):
                        if plan.capacity == 0:
                            continue  # empty buckets dispatch nothing
                        if plan.shape[0] > plan.shape[1]:
                            # paper: det = 0 for m > n — known at plan
                            # time, so no stack, no upload, no pipeline
                            self._complete_trivial(plan)
                            continue
                        try:
                            dev, cts = self._stage_one(plan)
                            exe = self._plan(plan.shape, plan.capacity)
                            # async dispatch: device work only — grad
                            # batches enter the plan's VJP program, value
                            # batches the forward executable
                            dets = exe.grad(dev, cts) if plan.grad \
                                else exe(dev)
                        except Exception as e:  # noqa: BLE001 — batch-local
                            # e.g. C(n, m) overflowing int32 for one weird
                            # shape: fail this batch, keep serving the rest
                            self._fail_plan(plan, e)
                            continue
                        # stats strictly before the hand-off: a caller woken
                        # by the batch's last future must see it counted
                        with self._lock:
                            st = self.stats
                            st["batches"] += 1
                            st["dispatches"] += 1  # m > n handled above
                            st["merged_requests"] += plan.merged_count
                            st["padded_slots"] += (plan.capacity
                                                   - len(plan.requests))
                        if not self._put_alive(self._inflight, (plan, dets)):
                            self._fail_plan(plan, self._fatal_now())
                            return
                    with self._lock:
                        self.stats["stage_s"] += time.perf_counter() - t0
                if closing:
                    self._put_alive(self._inflight, _SHUTDOWN)
                    return
        except BaseException as e:  # noqa: BLE001 — must not hang futures
            self._fail_all(e)  # also plants a shutdown sentinel downstream

    def _completer(self):
        try:
            while True:
                item = self._inflight.get()
                if isinstance(item, _Shutdown):
                    return
                plan, dets = item
                t0 = time.perf_counter()
                try:
                    vals = np.asarray(jax.block_until_ready(dets))
                except Exception as e:  # noqa: BLE001 — batch-local
                    self._fail_plan(plan, e)
                    continue
                k = len(plan.requests)
                m, n = plan.shape
                # grad batches deliver the (m, n) arrays themselves;
                # value batches unpack the (capacity,) dets to floats
                outs = list(vals[:k]) if plan.grad else vals[:k].tolist()
                self._deliver(plan, outs,
                              ranks=comb(n, m) * k,
                              complete_s=time.perf_counter() - t0)
        except BaseException as e:  # noqa: BLE001
            self._fail_all(e)
