"""Multi-worker bucket-routing determinant serving front.

The paper's rank space C(n, m) is a property of the request's *shape*:
one (m, n) class is one compiled program, one Pascal table, one plan in
the engine's cache.  The scaling unit of the serving tier is therefore
the **plan**, not the request — so the front routes every submitted
matrix by its canonical plan-family key (:func:`route_key`, the
``(m, n, capacity, dtype, x64)`` projection of the engine's
:class:`~repro.core.engine.PlanKey` space) over a consistent-hash ring
of workers, with *bounded-load* placement:
plan keys are few, so raw arc ownership splits load as a handful of
coin flips — instead the front walks the key's clockwise ring order and
takes the first worker whose accumulated plan weight stays within
``1 + eps`` of the fair share, weighting each plan family by its exact
per-request device work ``C(n, m)`` (:class:`PlanPlacer`).  Each worker
owns a disjoint set of plan families and runs its own
:class:`~repro.launch.det_queue.DetQueue` +
:class:`~repro.core.engine.DetEngine`, so:

* no plan is XLA-compiled twice across the pool (ownership is exclusive
  while the membership is stable);
* each worker's executable cache stays LRU-bounded exactly as in the
  single-process queue — the pool bound is the sum of the per-worker
  bounds;
* membership changes move only the keys owned by the changed worker
  (the consistent-hashing property), and because plans are pure
  functions of their key, a re-routed request re-plans on its new owner
  and reproduces **bit-identical** results — bit-identical under a
  capacity-pinning policy (``pin_capacity``: one program shape per
  bucket, so batch re-grouping on the new owner cannot select a
  different XLA specialization; see DESIGN_SERVE.md), numerically tight
  either way.

The wire is a pluggable :class:`~repro.launch.transport.Transport`
(DESIGN_FRONT.md has the protocol spec):

    submit()/submit_many() ──route──► per-worker WorkerLink.send
        ──[worker: DetQueue + DetEngine]──► response frames
        ──[one front drainer thread: wait over link waitables]──►
        futures + poll()

:class:`~repro.launch.transport.LocalTransport` (default) is the
spawn + Queue/Pipe single-host pool; :class:`~repro.launch.transport
.SocketTransport` (``det_serve --connect``) is the multi-host pool over
TCP worker daemons.  Routing, placement, re-route semantics and stats
aggregation are transport-blind: peer death — a process sentinel, a
socket EOF, a torn frame, a heartbeat deadline, or an unacknowledged
batch past ``ack_timeout_s`` — always funnels into the same
deterministic re-route of the dead worker's pending requests.

The front exposes the same surface as ``DetQueue`` — ``submit`` /
``submit_many`` / ``poll`` / ``serve`` / ``snapshot`` / ``close`` —
with futures resolved across the transport by the drainer thread.
:class:`~repro.launch.det_queue.LoadShedError` propagates end-to-end
(per-worker ``max_pending`` admission control) and ``snapshot()``
aggregates every worker's stats into one report (with a ``degraded``
flag instead of an exception when a worker dies mid-snapshot).

See DESIGN_FRONT.md for the routing/failure semantics,
``tests/test_det_front.py`` for the bit-identity battery and
``tests/test_transport_faults.py`` for the fault-injection battery.
"""

from __future__ import annotations

import bisect
import math
import socket
import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from multiprocessing import connection as mp_connection

import numpy as np

from repro.core.engine import stable_key_hash
from repro.launch.det_queue import (BucketPolicy, LoadShedError,
                                    QueueClosedError, drain_responses,
                                    prepare_matrix, resolve_future)
from repro.launch.transport import (FrameDecoder, LocalTransport, ShmTransport,
                                    SocketLink,
                                    Transport, TransportError, WorkerConfig,
                                    _read_frame, encode_frame, parse_hostport)
from repro.runtime.watchdog import StepTimer, Watchdog

__all__ = ["DetFront", "HashRing", "PlanPlacer", "WorkerError", "route_key"]


class WorkerError(RuntimeError):
    """A worker-side evaluation error whose concrete type could not be
    reconstructed across the process boundary; carries
    ``type name: message``."""


def route_key(shape: tuple[int, int], policy: BucketPolicy, dtype,
              x64: bool) -> tuple[int, int, int, str, bool]:
    """Canonical plan routing key ``(m, n, capacity, dtype, x64)`` for a
    request shape under a bucket policy.

    ``(m, n)`` is the policy's *canonical* shape whenever merging is
    possible (``auto``/``merge``): every exact shape that could ever be
    column-padded into the same canonical bucket must land on the same
    worker, or a merge would compile its program on two hosts.  The
    capacity component is the policy's batch bound — the plan family's
    capacity class; the per-batch exact capacities a worker compiles all
    belong to the family it owns.
    """
    m, n = int(shape[0]), int(shape[1])
    if policy.mode in ("auto", "merge"):
        m, n = policy.canonical_shape(m, n)
    return (m, n, policy.max_batch, np.dtype(dtype).name, bool(x64))


class HashRing:
    """Consistent-hash ring: stable key → worker id, with virtual nodes.

    Placement uses :func:`repro.core.engine.stable_key_hash`, so it is
    identical across processes and restarts (no ``PYTHONHASHSEED``
    dependence).  Removing a worker moves only the keys it owned to
    their next clockwise owner — the deterministic re-route target after
    a worker death.
    """

    def __init__(self, workers, vnodes: int = 64):
        if vnodes < 1:
            raise ValueError("vnodes must be >= 1")
        self.vnodes = vnodes
        self._points: list[tuple[int, int]] = []  # sorted (point, worker)
        for w in workers:
            self.add(int(w))

    def add(self, worker: int) -> None:
        for v in range(self.vnodes):
            pt = stable_key_hash(("det-front-vnode", worker, v))
            bisect.insort(self._points, (pt, worker))

    def remove(self, worker: int) -> None:
        self._points = [(p, w) for p, w in self._points if w != worker]

    def __len__(self) -> int:
        return len({w for _, w in self._points})

    def owner(self, key) -> int:
        """The worker owning ``key``: first ring point clockwise of the
        key's stable hash (wrapping)."""
        if not self._points:
            raise RuntimeError("hash ring is empty (no live workers)")
        pt = stable_key_hash(key)
        i = bisect.bisect_right(self._points, (pt, -1))
        if i == len(self._points):
            i = 0
        return self._points[i][1]

    def walk(self, key) -> list[int]:
        """Every distinct worker in clockwise ring order from the key's
        point — the deterministic candidate sequence for bounded-load
        placement (the plain ``owner`` is ``walk(key)[0]``)."""
        if not self._points:
            return []
        pt = stable_key_hash(key)
        i = bisect.bisect_right(self._points, (pt, -1))
        n = len(self._points)
        seen: set[int] = set()
        order: list[int] = []
        for j in range(n):
            w = self._points[(i + j) % n][1]
            if w not in seen:
                seen.add(w)
                order.append(w)
        return order


class PlanPlacer:
    """Bounded-load, sticky plan-family placement over a
    :class:`HashRing` — pure state, no transport, no processes (the
    property tests drive it directly).

    Placement: take the first worker on the key's clockwise ring walk
    whose load (summed weights of owned plan families) stays within
    ``1 + eps`` of the fair share, falling back to the least-loaded
    worker.  The weight of a plan family is its exact per-request
    device work ``C(n, m)``.  Ownership is sticky (memoized) until the
    owner leaves, so every request of a family keeps hitting the one
    worker that compiled it.  The owner map is LRU-bounded
    (``max_families``): a long-tail shape stream must not grow the
    router's memory or permanently skew the load vector with weights of
    families that never recur — an evicted family simply re-assigns on
    next sight, the router analogue of an evicted plan re-planning.

    Not thread-safe on its own; the front serializes calls under its
    lock.
    """

    def __init__(self, worker_ids, *, vnodes: int = 64, eps: float = 0.25,
                 max_families: int = 128):
        self.ring = HashRing(worker_ids, vnodes=vnodes)
        self.eps = float(eps)
        self.max_families = int(max_families)
        self.owner_map: OrderedDict[tuple, int] = OrderedDict()
        self.load: dict[int, float] = {int(w): 0.0 for w in worker_ids}

    @staticmethod
    def key_weight(key: tuple) -> float:
        """A plan family's per-request device work: its rank-space size
        C(n, m) (1 for the degenerate m > n families).  Capped before
        the float conversion — an astronomically wide shape must not
        raise OverflowError mid-submit (the request itself still fails
        properly at plan time on its own future)."""
        m, n = int(key[0]), int(key[1])
        if m > n:
            return 1.0
        return float(min(math.comb(n, m), 10 ** 18))

    def assign(self, key: tuple, usable=None) -> int:
        """The key's current owner, assigning one on first sight.

        ``usable(wid)`` filters the routable workers (the front passes
        its liveness predicate); a worker must also still hold a load
        entry — a retiring worker stays alive to finish in-flight work
        but left the load map (and the ring) at retire time, so it
        never receives new or re-routed families.
        """
        wid = self.owner_map.get(key)
        if wid is not None and wid in self.load \
                and (usable is None or usable(wid)):
            self.owner_map.move_to_end(key)
            return wid
        routable = [a for a in self.load
                    if usable is None or usable(a)]
        if not routable:
            raise RuntimeError("no routable workers")
        wt = self.key_weight(key)
        total = sum(self.load[a] for a in routable) + wt
        bound = total * (1.0 + self.eps) / len(routable)
        pick = None
        for cand in self.ring.walk(key):
            if cand in routable and self.load[cand] + wt <= bound:
                pick = cand
                break
        if pick is None:
            pick = min(routable, key=lambda a: self.load[a])
        self.owner_map[key] = pick
        self.load[pick] += wt
        while len(self.owner_map) > self.max_families:
            old_key, old_wid = self.owner_map.popitem(last=False)
            if old_wid in self.load:
                self.load[old_wid] = max(
                    0.0, self.load[old_wid] - self.key_weight(old_key))
        return pick

    def release(self, wid: int) -> None:
        """Forget a departing worker's plan ownership so its families
        re-assign to the survivors on next sight."""
        for key in [k for k, o in self.owner_map.items() if o == wid]:
            del self.owner_map[key]
        self.load.pop(wid, None)

    def remove(self, wid: int) -> None:
        """Take a worker out of both the ring and the load map."""
        self.ring.remove(wid)
        self.release(wid)

    def add(self, wid: int) -> None:
        """Admit a worker into the ring and the load map (live join /
        rejoin).  Monotone by construction: the new node steals only the
        ring arcs its vnodes land on, and the sticky ``owner_map`` keeps
        every *already-assigned* family on the worker that compiled it —
        the joiner picks up only families first seen (or re-assigned
        after an eviction/death) from now on.  Idempotent per id."""
        wid = int(wid)
        if wid not in self.load:
            self.ring.add(wid)
            self.load[wid] = 0.0


# -------------------------------------------------------------- front side
@dataclass
class _FrontRequest:
    """Front-side record of one routed request: enough to re-route it
    bit-identically if its worker dies before responding.  ``grad``
    requests carry their scalar cotangent ``ct`` (the determinant is
    scalar-valued, so one float is the whole cotangent payload)."""
    seq: int
    array: np.ndarray
    shape: tuple[int, int]
    future: Future
    grad: bool = False
    ct: float = 1.0
    t_submit: float = field(default_factory=time.perf_counter)

    def wire_pair(self) -> tuple:
        """The request's slot in a ``("batch", bid, pairs)`` message:
        ``(seq, arr)`` for a value request, ``(seq, arr, ct)`` for a
        gradient request — same triple on first routing and on every
        re-route, so a death cannot change what a request computes."""
        if self.grad:
            return (self.seq, self.array, self.ct)
        return (self.seq, self.array)


class _WorkerHandle:
    __slots__ = ("id", "link", "pending", "unacked", "alive", "clean",
                 "joined", "timer")

    def __init__(self, link, *, joined: bool = False,
                 timer: StepTimer | None = None):
        self.id = link.id
        self.link = link
        self.pending: dict[int, _FrontRequest] = {}
        self.unacked: dict[int, float] = {}  # batch id -> monotonic send t
        self.alive = True
        self.clean = False  # saw the worker's "bye"
        self.joined = joined  # admitted via live join (no transport entry)
        # per-worker completion-latency EMA (straggler health signal);
        # mutated only under the front's lock
        self.timer = timer if timer is not None else StepTimer()


_EXC_TYPES: dict[str, type[BaseException]] = {
    "LoadShedError": LoadShedError,
    "QueueClosedError": QueueClosedError,
    "OverflowError": OverflowError,
    "ValueError": ValueError,
    "RuntimeError": RuntimeError,
}


def _rebuild_exc(name: str, text: str) -> BaseException:
    cls = _EXC_TYPES.get(name)
    if cls is not None:
        return cls(text)
    return WorkerError(f"{name}: {text}")


class DetFront:
    """Horizontally scaled determinant serving: N workers behind a
    pluggable transport, one ``DetQueue`` + ``DetEngine`` each, requests
    routed by canonical plan key over a consistent-hash ring.

    >>> with DetFront(workers=2, max_batch=32) as front:
    ...     fut = front.submit(np.ones((2, 5), np.float32))
    ...     det = fut.result(timeout=60)

    ``transport`` selects the wire: the default is
    ``LocalTransport(workers)`` (spawned processes on this host); pass a
    :class:`~repro.launch.transport.SocketTransport` to serve over
    remote ``det_serve --listen`` daemons instead (``workers`` is then
    taken from the transport's address list).  ``shm=True`` upgrades
    the default same-host pool to
    :class:`~repro.launch.transport.ShmTransport` — matrix payloads
    ride a per-link shared-memory ring instead of the pickled queue,
    bit-identical results (``det_serve --shm``).

    Same contract as ``DetQueue``: ``submit`` returns a ``Future``
    carrying ``.seq``; every submitted seq appears on the ``poll()``
    stream exactly once (results, sheds and errors alike);
    ``close()`` is idempotent and never strands a future.
    """

    # reprolint lock-discipline registry (see DESIGN_LINT.md).  The
    # router lock is re-entrant (death path nests); the response deque
    # and the drainer's end-of-stream flag live under the response cv;
    # ``_stats_cv`` shares ``_lock``, so either name is the same mutex
    # for the stats-report attributes.
    _GUARDED_BY = {
        "_seq": ("_lock",),
        "_bid": ("_lock",),
        "_closing": ("_lock",),
        "_next_wid": ("_lock",),
        "_last_drain_t": ("_lock",),
        "stats": ("_lock",),
        "_stats_token": ("_lock", "_stats_cv"),
        "_stats_reports": ("_lock", "_stats_cv"),
        "_drained": ("_resp_cv",),
        "_responses": ("_resp_cv",),
        "_cold_wids": ("_lock",),
    }

    def __init__(self, workers: int = 2, *, transport: Transport | None = None,
                 chunk: int = 2048,
                 backend: str = "jnp", dtype=np.float32,
                 max_batch: int | None = None,
                 policy: BucketPolicy | None = None,
                 max_pending: int | None = None, plan_cache: int = 128,
                 linger_s: float = 0.0, stage_depth: int | None = None,
                 pipeline_depth: int = 8, pin_workers: bool = False,
                 vnodes: int = 64, response_buffer: int = 65536,
                 ack_timeout_s: float | None = None,
                 accept: str | None = None,
                 accept_heartbeat_s: float = 1.0,
                 accept_heartbeat_misses: int = 5,
                 straggler_factor: float | None = None,
                 straggler_warmup: int = 8,
                 straggler_cooldown_s: float = 5.0,
                 watchdog_s: float | None = None,
                 mp_context: str = "spawn",
                 shm: bool = False, shm_ring_bytes: int = 8 << 20,
                 persist_dir: str | None = None,
                 prefill: bool | None = None):
        if policy is None:
            policy = BucketPolicy(
                max_batch=64 if max_batch is None else max_batch)
        elif max_batch is not None and max_batch != policy.max_batch:
            raise ValueError(
                f"conflicting max_batch: argument {max_batch} vs "
                f"policy.max_batch {policy.max_batch} — set it on the "
                "policy only")
        import jax  # local: only the x64 flag is read front-side

        self.policy = policy
        self.dtype = np.dtype(dtype)
        self._x64 = bool(jax.config.jax_enable_x64)
        # the wire: sends, receives and peer-death signals all live
        # behind the links; everything below is transport-blind.
        # ``shm=True`` selects the zero-copy same-host ring for the
        # default (spawned, same-host) worker pool — it never applies
        # to an explicit transport, which may be remote.
        if transport is None:
            if shm:
                transport = ShmTransport(workers, mp_context=mp_context,
                                         ring_bytes=shm_ring_bytes)
            else:
                transport = LocalTransport(workers, mp_context=mp_context)
        self._transport = transport
        cfg = WorkerConfig(chunk=int(chunk), backend=backend,
                           dtype=self.dtype.name, policy=policy,
                           max_pending=max_pending,
                           plan_cache=int(plan_cache),
                           linger_s=float(linger_s),
                           stage_depth=stage_depth,
                           pipeline_depth=int(pipeline_depth),
                           x64=self._x64, pin_workers=bool(pin_workers),
                           persist_dir=persist_dir)
        self._cfg = cfg
        # plan-family warm-start (DESIGN_PERSIST.md): joining workers
        # are shipped the live routing working set as a prefill list so
        # they plan (store first, compile second) before admission.
        # Default: on whenever a plan store is configured.
        self._prefill_enabled = (bool(prefill) if prefill is not None
                                 else persist_dir is not None)
        # workers the autoscaler currently judges cold (low plan-cache
        # hit rate, typically still compiling after a join): shielded
        # from the straggler sweep so warm-up latency is never read as
        # slowness
        self._cold_wids: set[int] = set()
        # the hello a live-joining worker receives over the accept
        # listener — identical in shape to SocketTransport's handshake,
        # so a dialed-in daemon and a --connect daemon build the same
        # queue from the same config source
        self._accept_hb_s = float(accept_heartbeat_s)
        self._accept_hb_timeout = (self._accept_hb_s
                                   * int(accept_heartbeat_misses)
                                   if self._accept_hb_s > 0 else None)
        wire_cfg = cfg.to_wire()
        wire_cfg["heartbeat_s"] = self._accept_hb_s
        self._wire_cfg = wire_cfg
        self._workers = [_WorkerHandle(link) for link in transport.start(cfg)]
        self._by_id = {w.id: w for w in self._workers}
        self._placer = PlanPlacer(
            [w.id for w in self._workers], vnodes=vnodes,
            max_families=max(64, int(plan_cache) * len(self._workers)))
        self._next_wid = max(w.id for w in self._workers) + 1
        # straggler health: drain a worker whose completion-latency EMA
        # is persistently worse than its peers' (None = disabled)
        self._straggler_factor = straggler_factor
        self._straggler_warmup = int(straggler_warmup)
        self._straggler_cooldown = float(straggler_cooldown_s)
        self._last_drain_t = 0.0
        # unacked-batch deadline: a worker acks every batch frame on
        # receipt, so this is an RTT/queueing-scale bound on frame loss
        # — deliberately NOT a compute deadline (the first batch of a
        # family legitimately sits in XLA compilation for seconds)
        self._ack_timeout = ack_timeout_s

        # reentrant: the death path (_on_worker_exit → _reroute) nests
        self._lock = threading.RLock()
        self._seq = 0
        self._bid = 0  # batch ids for the ack protocol
        self._closing = False
        self._drained = False  # drainer exited: the response stream is over
        self._responses: deque = deque(maxlen=response_buffer)
        self._resp_cv = threading.Condition()
        self._stats_cv = threading.Condition(self._lock)
        self._stats_token = 0
        self._stats_reports: dict[int, dict] = {}
        self.stats = self._zero_stats([w.id for w in self._workers])

        # runtime watchdog over the drainer: the drainer beats every
        # loop pass, so a wedged drain (a pump stuck in a pathological
        # link) surfaces as a counted stall instead of a silently
        # frozen response stream.  Built strictly before the drainer
        # thread starts — the loop reads the attribute.
        self._watchdog: Watchdog | None = None
        if watchdog_s is not None:
            self._watchdog = Watchdog(float(watchdog_s),
                                      self._note_drainer_stall).start()

        # live-join listener: a `det_serve --join host:port` daemon dials
        # in, the front assigns it a fresh worker id and admits it
        self._accept_srv: socket.socket | None = None
        self._accept_thread: threading.Thread | None = None
        self.accept_address: str | None = None
        if accept is not None:
            host, port = parse_hostport(accept, default_host="127.0.0.1")
            self._accept_srv = socket.create_server((host, port))
            bound = self._accept_srv.getsockname()
            self.accept_address = f"{bound[0]}:{bound[1]}"
            self._accept_srv.settimeout(0.25)
            self._accept_thread = threading.Thread(
                target=self._accept_loop, name="det-front-accept",
                daemon=True)

        self._drainer = threading.Thread(target=self._drain_loop,
                                         name="det-front-drainer",
                                         daemon=True)
        self._drainer.start()
        if self._accept_thread is not None:
            self._accept_thread.start()

    @staticmethod
    def _zero_stats(worker_ids) -> dict:
        return {"submitted": 0, "completed": 0, "shed": 0, "errors": 0,
                "rerouted": 0, "worker_deaths": 0,
                "routed": {wid: 0 for wid in worker_ids},
                "stragglers_drained": 0, "drainer_stalls": 0,
                "joined": 0, "responses_dropped": 0}

    def _note_drainer_stall(self) -> None:
        with self._lock:
            self.stats["drainer_stalls"] += 1

    # ------------------------------------------------------------- routing
    @property
    def _balance_eps(self) -> float:
        return self._placer.eps

    def route_key(self, shape: tuple[int, int]) -> tuple:
        """The stable routing key for a request shape under this front's
        policy/dtype/x64 — ``(m, n, capacity, dtype, x64)``."""
        return route_key(shape, self.policy, self.dtype, self._x64)

    def _owner(self, key: tuple) -> int:
        """The key's current owner (assigning on first sight).  Callers
        hold ``self._lock``."""
        try:
            return self._placer.assign(
                key, lambda wid: self._by_id[wid].alive)
        except RuntimeError:
            raise RuntimeError("DetFront has no live workers") from None

    def owner_of(self, shape: tuple[int, int]) -> int:
        """Which live worker currently owns a request shape (tests and
        chaos tooling: pick the right victim)."""
        with self._lock:
            return self._owner(self.route_key(shape))

    @property
    def alive_workers(self) -> list[int]:
        with self._lock:
            return [w.id for w in self._workers if w.alive]

    def describe_links(self) -> list[str]:
        """One transport descriptor per live worker link — ``local(…)``,
        ``shm(pid=…, ring=…)``, ``socket(…)`` — for ops/debug output and
        for tests asserting which wire a front actually selected."""
        with self._lock:
            return [w.link.describe() for w in self._workers if w.alive]

    # -------------------------------------------------------------- submit
    def _prepare(self, A) -> np.ndarray:
        return prepare_matrix(A, self.dtype)

    def submit(self, A, *, grad: bool = False,
               cotangent: float = 1.0) -> Future:
        """Route and enqueue one matrix; returns a ``Future`` with
        ``.seq``.  ``grad=True`` requests the VJP instead of the value:
        the future resolves to the (m, n) gradient ndarray
        ``cotangent · ∂det/∂A`` (see DESIGN_GRAD.md)."""
        return self._submit_prepared(
            [self._prepare(A)], [(bool(grad), float(cotangent))])[0]

    def submit_many(self, mats, grads=None) -> list[Future]:
        """Route and enqueue a burst: one message per owning worker, so
        each worker's stager sees a deep snapshot (full batches), not a
        trickle of singletons.  ``grads`` mirrors
        ``DetQueue.submit_many``: one ``(grad, cotangent)`` pair per
        matrix (``None`` = all value requests)."""
        return self._submit_prepared(
            [self._prepare(A) for A in mats],
            None if grads is None
            else [(bool(g), float(ct)) for g, ct in grads])

    def _send_batches(self, batches: dict[int, list]) -> None:
        """One framed ``batch`` message per owning worker, stamped with
        a batch id the worker acks on receipt.  A send failure does not
        raise: the link is broken, the drainer's next sweep declares the
        worker dead and re-routes its pending (including what we just
        routed to it).  Takes the (re-entrant) router lock itself, so it
        is safe from any caller."""
        with self._lock:
            for wid, pairs in batches.items():
                w = self._by_id[wid]
                bid = self._bid
                self._bid += 1
                w.unacked[bid] = time.monotonic()
                try:
                    w.link.send(("batch", bid, pairs))
                except TransportError as e:
                    w.unacked.pop(bid, None)
                    if w.link.broken:
                        continue  # peer gone: the sweep re-routes w.pending
                    # the link is healthy but this frame cannot be sent
                    # (e.g. an over-the-limit payload): re-routing would
                    # hit the same wall on every worker — fail these
                    for pr in pairs:
                        self._complete(w, pr[0], exc=e)

    def _submit_prepared(self, arrs: list[np.ndarray],
                         grads: list[tuple[bool, float]] | None = None
                         ) -> list[Future]:
        if grads is None:
            grads = [(False, 1.0)] * len(arrs)
        if len(grads) != len(arrs):
            raise ValueError("grads must match the matrices one-to-one")
        futs: list[Future] = []
        with self._lock:
            if self._closing:
                raise QueueClosedError("DetFront is closed")
            if not any(w.alive for w in self._workers):
                raise RuntimeError("DetFront has no live workers")
            batches: dict[int, list[tuple]] = {}
            for arr, (grad, ct) in zip(arrs, grads):
                shape = (int(arr.shape[0]), int(arr.shape[1]))
                # grad and value requests of one shape share the plan
                # family (same key → same worker): the backward reuses
                # the forward's plan, so splitting them would compile
                # the family twice across the pool for nothing
                wid = self._owner(self.route_key(shape))
                seq = self._seq
                self._seq += 1
                fut = Future()
                fut.seq = seq
                req = _FrontRequest(seq=seq, array=arr, shape=shape,
                                    future=fut, grad=grad, ct=ct)
                self._by_id[wid].pending[seq] = req
                self.stats["submitted"] += 1
                self.stats["routed"][wid] += 1
                batches.setdefault(wid, []).append(req.wire_pair())
                futs.append(fut)
            self._send_batches(batches)
        return futs

    # ---------------------------------------------------------- responses
    _resolve = staticmethod(resolve_future)

    def _complete(self, w: _WorkerHandle, seq: int, val=None,
                  exc: BaseException | None = None) -> None:
        with self._lock:
            req = w.pending.pop(seq, None)
            if req is None:
                return  # completed right before a kill we already re-routed
            # mirror DetQueue's counter semantics: "completed" is
            # delivered results only; sheds and errors get their own
            # counters (a response of any kind is still exactly one)
            if isinstance(exc, LoadShedError):
                self.stats["shed"] += 1
            elif exc is not None:
                self.stats["errors"] += 1
            else:
                self.stats["completed"] += 1
                # delivered results feed the worker's latency EMA — the
                # straggler-health signal (sheds return on admission
                # scale and would make a drowning worker look fast)
                w.timer.record(seq, time.perf_counter() - req.t_submit)
        # responses (and stats above) strictly before the future resolves,
        # mirroring DetQueue._deliver's ordering contract
        with self._resp_cv:
            dropped = max(0, len(self._responses) + 1
                          - (self._responses.maxlen or 0))
            self._responses.append((seq, val if exc is None else exc))
            self._resp_cv.notify_all()
        if dropped:
            with self._lock:
                self.stats["responses_dropped"] += dropped
        self._resolve(req.future, val=val, exc=exc)

    def _handle_msg(self, w: _WorkerHandle, msg) -> None:
        kind = msg[0]
        if kind == "result":
            self._complete(w, msg[1], val=msg[2])
        elif kind == "ack":
            with self._lock:
                w.unacked.pop(msg[1], None)
        elif kind == "shed":
            self._complete(w, msg[1], exc=LoadShedError(msg[2]))
        elif kind == "error":
            self._complete(w, msg[1], exc=_rebuild_exc(msg[2], msg[3]))
        elif kind == "requeue":
            # a retiring worker handed back an un-staged request: route it
            # to its next owner (the worker left the ring at retire time)
            with self._lock:
                req = w.pending.pop(msg[1], None)
                if req is not None:
                    self._reroute([req])
        elif kind == "stats":
            with self._lock:
                if msg[3] == self._stats_token:
                    self._stats_reports[msg[1]] = msg[2]
                    self._stats_cv.notify_all()
        elif kind == "bye":
            w.clean = True

    # ------------------------------------------------- death and re-routing
    def _reroute(self, orphans: list[_FrontRequest]) -> None:
        """Deterministically re-dispatch requests whose worker went away.

        The dead/retired worker is already off the ring, so ``owner()``
        yields each key's next clockwise owner — the same answer for the
        same key on every front instance (stable hashing).  Plans are
        pure functions of their key, so the new owner reproduces the
        same results — bit-identical when the policy pins capacity (one
        program shape per bucket; otherwise re-grouping may select a
        different batch-size specialization, the capacity effect
        DESIGN_SERVE.md documents).
        """
        with self._lock:
            orphans = sorted(orphans, key=lambda r: r.seq)
            alive = [w for w in self._workers
                     if w.alive and w.id in self._placer.load]
            if not alive:
                exc = RuntimeError("DetFront: all workers are gone")
                with self._resp_cv:
                    self._responses.extend((r.seq, exc) for r in orphans)
                    self._resp_cv.notify_all()
                for r in orphans:
                    self._resolve(r.future, exc=exc)
                return
            batches: dict[int, list[tuple]] = {}
            for req in orphans:
                wid = self._owner(self.route_key(req.shape))
                self._by_id[wid].pending[req.seq] = req
                self.stats["rerouted"] += 1
                batches.setdefault(wid, []).append(req.wire_pair())
            self._send_batches(batches)

    def _on_worker_exit(self, w: _WorkerHandle) -> None:
        with self._lock:
            if not w.alive:
                return
            w.alive = False
            self._placer.remove(w.id)
            orphans = list(w.pending.values())
            w.pending.clear()
            w.unacked.clear()
            if not w.clean:
                self.stats["worker_deaths"] += 1
            self._stats_cv.notify_all()  # a stats() waiter stops expecting it
        w.link.join(timeout=5)
        if orphans:
            self._reroute(orphans)

    def _expire_worker(self, w: _WorkerHandle) -> None:
        """A transport-level death verdict (broken link, heartbeat
        deadline, unacked batch): surface whatever responses are still
        buffered, then kill the link and re-route the rest."""
        msgs, _ = w.link.pump()
        for m in msgs:
            self._handle_msg(w, m)
        try:
            w.link.kill()
        except Exception:  # noqa: BLE001 — already half-dead links differ
            pass
        self._on_worker_exit(w)

    def _drain_loop(self) -> None:
        try:
            self._drain_loop_inner()
        finally:
            # backstop for an exception path: the flag must be set even
            # if the loop died, or every poller would wait forever
            with self._resp_cv:
                self._drained = True
                self._resp_cv.notify_all()

    def _drain_loop_inner(self) -> None:
        while True:
            with self._lock:
                live = [w for w in self._workers if w.alive]
                if not live:
                    # set the end-of-stream flag atomically with the
                    # liveness check (under self._lock): a concurrent
                    # reconnect_worker serializes behind this lock and
                    # therefore either revives a worker before we look
                    # (we keep looping) or observes _drained and
                    # restarts the drainer — never a live worker with
                    # no drainer
                    with self._resp_cv:
                        self._drained = True
                        self._resp_cv.notify_all()
                    return  # clean shutdown or total loss
            waitmap: dict = {}
            for w in live:
                for obj in w.link.waitables():
                    waitmap.setdefault(obj, w)
            try:
                ready = mp_connection.wait(list(waitmap), timeout=0.2) \
                    if waitmap else []
                if not waitmap:
                    time.sleep(0.05)  # all links broken; sweep below acts
            except (OSError, ValueError):
                ready = []  # a handle closed under us mid-wait; sweep below
            woken: list[_WorkerHandle] = []
            seen: set[int] = set()
            for obj in ready:
                w = waitmap[obj]
                if id(w) not in seen:
                    seen.add(id(w))
                    woken.append(w)
            for w in woken:
                msgs, dead = w.link.pump()
                for m in msgs:
                    self._handle_msg(w, m)
                if dead:
                    self._on_worker_exit(w)
            # transport-level death sweep: verdicts no waitable can
            # signal — a broken/killed link, a peer silent past its
            # heartbeat deadline, a batch unacked past the ack bound
            now = time.monotonic()
            for w in live:
                if not w.alive:
                    continue
                with self._lock:  # submit/ack paths mutate unacked
                    stale = self._ack_timeout is not None and any(
                        now - t > self._ack_timeout
                        for t in w.unacked.values())
                if w.link.broken or w.link.expired(now) or stale:
                    self._expire_worker(w)
            # straggler verdicts ride the same sweep: persistently slow
            # workers get a graceful drain, not just dead ones
            if self._straggler_factor is not None:
                self._sweep_stragglers(now)
            if self._watchdog is not None:
                self._watchdog.beat()

    def _sweep_stragglers(self, now: float) -> None:
        """Drain (retire) a worker whose completion-latency EMA is
        persistently worse than its peers' — ``straggler_factor`` × the
        median of the *other* warmed workers.  At most one drain per
        ``straggler_cooldown_s`` (hysteresis: the survivors' EMAs need
        time to absorb the re-routed families before the next verdict),
        and never below two routable workers (a pool of one has no
        baseline and no re-route target).
        """
        victim = None
        with self._lock:
            if now - self._last_drain_t < self._straggler_cooldown:
                return
            # cold workers (per the autoscaler's plan-cache hit-rate
            # signal) are excluded on both sides of the comparison: a
            # joiner still compiling its families must neither be
            # drained for warming up nor drag the peer baseline
            warmed = [(w, w.timer.ema) for w in self._workers
                      if w.alive and w.id in self._placer.load
                      and w.id not in self._cold_wids
                      and w.timer.ema is not None
                      and w.timer.n >= self._straggler_warmup]
            if len(warmed) >= 2:
                worst, worst_ema = max(warmed, key=lambda t: t[1])
                others = sorted(e for w, e in warmed if w is not worst)
                baseline = others[len(others) // 2]
                if worst_ema > self._straggler_factor * baseline:
                    victim = worst
                    self._last_drain_t = now
                    self.stats["stragglers_drained"] += 1
        if victim is not None:
            self.retire_worker(victim.id)

    # ------------------------------------------------------ poll and serve
    def poll(self, max_items: int | None = None,
             timeout: float | None = 0.0) -> list[tuple[int, float]]:
        """Drain completed ``(seq, det)`` responses — same contract as
        ``DetQueue.poll``: waits up to ``timeout`` for the first item,
        then drains what's ready; errored/shed requests deliver their
        exception instance; every seq appears exactly once."""
        # the drainer is the only producer of new responses: once it has
        # flagged itself drained (clean close OR total worker loss),
        # every response that will ever exist is already in the deque —
        # a flag, not thread-liveness, because a poller woken by the
        # drainer's final notify could still observe the thread alive
        def eos():
            with self._resp_cv:  # re-entrant under drain_responses' hold
                return self._drained
        # the deque reference is immutable after __init__; drain_responses
        # does every mutation under the cv it is handed here
        return drain_responses(self._responses, self._resp_cv,  # reprolint: disable=lock-discipline
                               eos, max_items, timeout)

    def serve(self, mats, timeout: float | None = None):
        """Submit everything, wait for everything; ``(dets, stats)``.
        Shed/errored requests surface as exceptions from the futures —
        use :meth:`submit_many` directly for shed-tolerant flows."""
        futs = self.submit_many(mats)
        dets = [f.result(timeout=timeout) for f in futs]
        self.poll(timeout=0)
        return dets, self.snapshot()

    # ---------------------------------------------------------------- stats
    def reset_stats(self) -> None:
        """Zero front counters and every worker's queue counters (FIFO
        request streams order the reset before any later batch)."""
        with self._lock:
            routed = {wid: 0 for wid in self.stats["routed"]}
            self.stats = self._zero_stats([])
            self.stats["routed"] = routed
            for w in self._workers:
                if w.alive:
                    try:
                        w.link.send(("reset",))
                    except TransportError:
                        pass  # dying worker: the sweep will collect it

    def snapshot(self, timeout: float = 30.0) -> dict:
        """One aggregated report over the whole pool.

        ``front`` holds the router's own counters, ``workers`` the
        per-worker ``DetQueue.snapshot()`` s (keyed by worker id), and
        ``total`` sums the scalar counters, merges the per-bucket stats
        and aggregates the plan caches (hits/misses/evictions summed,
        ``backlog_peak`` maxed) — the single pane the CLI prints.

        Never raises on a worker that died between the liveness check
        and its stats reply (or whose link refused the send): the
        report is returned with whatever workers answered and
        ``front["degraded"] = True`` — partial observability of a
        degraded pool is still observability.
        """
        with self._lock:
            alive = [w for w in self._workers if w.alive]
            self._stats_token += 1
            token = self._stats_token
            self._stats_reports = {}
            asked: list[_WorkerHandle] = []
            for w in alive:
                try:
                    w.link.send(("stats", token))
                    asked.append(w)
                except TransportError:
                    pass  # dead between liveness check and request
            deadline = time.monotonic() + timeout
            # a worker dying mid-wait notifies the cv and drops out of
            # the expected count (its report will never come)
            while len(self._stats_reports) < sum(
                    1 for w in asked if w.alive):
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._stats_cv.wait(remaining)
            reports = dict(self._stats_reports)
            degraded = len(reports) < len(alive)
            front = {k: (dict(v) if isinstance(v, dict) else v)
                     for k, v in self.stats.items()}
            front["workers_alive"] = sum(1 for w in self._workers if w.alive)
            front["workers_total"] = len(self._workers)
            front["plan_load"] = dict(self._placer.load)
            front["plan_families"] = len(self._placer.owner_map)
            front["degraded"] = degraded
            # autoscaler inputs: per-worker front-side backlog and the
            # completion-latency EMA the straggler sweep reads
            front["pending"] = {w.id: len(w.pending)
                                for w in self._workers if w.alive}
            front["latency_ema_s"] = {w.id: w.timer.ema
                                      for w in self._workers
                                      if w.alive and w.timer.ema is not None}
            front["accept_address"] = self.accept_address
            front["cold_workers"] = sorted(self._cold_wids)
            front["prefill"] = self._prefill_enabled
        return {"front": front, "workers": reports,
                "total": self._aggregate(reports)}

    @staticmethod
    def _aggregate(reports: dict[int, dict]) -> dict:
        total = {"submitted": 0, "completed": 0, "batches": 0,
                 "dispatches": 0, "merged_requests": 0, "padded_slots": 0,
                 "ranks": 0, "shed": 0, "backlog_peak": 0,
                 "responses_dropped": 0, "buckets": {},
                 "plan_cache": {"size": 0, "max_plans": 0, "hits": 0,
                                "misses": 0, "evictions": 0,
                                "store_hits": 0, "store_misses": 0}}
        for snap in reports.values():
            for k in ("submitted", "completed", "batches", "dispatches",
                      "merged_requests", "padded_slots", "ranks", "shed",
                      "responses_dropped"):
                total[k] += snap.get(k, 0)
            total["backlog_peak"] = max(total["backlog_peak"],
                                        snap.get("backlog_peak", 0))
            for shape, b in snap.get("buckets", {}).items():
                agg = total["buckets"].setdefault(
                    shape, {"count": 0, "batches": 0, "ranks": 0,
                            "wait_s": 0.0})
                for k in agg:
                    agg[k] += b.get(k, 0)
            pc = snap.get("plan_cache", {})
            for k in total["plan_cache"]:
                total["plan_cache"][k] += pc.get(k, 0)
        return total

    # ----------------------------------------------------- dynamic membership
    def _prefill_entries(self) -> list:
        """The live routing working set as a wire-plain prefill list.

        One ``(m, n, capacity)`` tuple per currently-assigned plan
        family, least-recently-used first (the joiner warms hot
        families last, so they are freshest in its LRU).  dtype/x64
        ride the worker config, not the list.
        """
        with self._lock:
            return [(int(k[0]), int(k[1]), int(k[2]))
                    for k in self._placer.owner_map]

    def mark_cold_workers(self, wids) -> None:
        """Record which workers the autoscaler currently judges cold
        (plan-cache hit rate below its threshold).  Cold workers are
        exempt from the straggler sweep — a joiner paying compile time
        must not read as a slow peer and get drained for warming up."""
        cold = {int(w) for w in wids}
        with self._lock:
            self._cold_wids = cold

    def _reserve_wid(self) -> int:
        with self._lock:
            if self._closing:
                raise QueueClosedError("DetFront is closed")
            wid = self._next_wid
            self._next_wid += 1
            return wid

    def _admit(self, link, *, joined: bool = False) -> int:
        """Admit a live link as a brand-new pool member (the join path's
        single synchronization point).

        Everything happens under the router lock, so admission is
        atomic with respect to routing: no batch can route to the
        joiner before its handle, ring arc and load entry all exist.
        The sticky ``owner_map`` (see :meth:`PlanPlacer.add`) keeps
        every in-flight and already-assigned family on its current
        owner — the joiner only picks up families first seen after this
        point, which is what keeps results bit-identical through a join
        (a family never half-moves between compiled programs).
        """
        w = _WorkerHandle(link, joined=joined)
        with self._lock:
            if self._closing:
                raise QueueClosedError("DetFront is closed")
            self._workers.append(w)
            self._by_id[w.id] = w
            self._placer.add(w.id)
            self.stats["routed"].setdefault(w.id, 0)
            self.stats["joined"] += 1
            # same revival dance as reconnect_worker: if total loss had
            # ended the response stream, the admitted worker restarts it
            with self._resp_cv:
                restart = self._drained
                if restart:
                    self._drained = False
            if restart:
                self._drainer = threading.Thread(target=self._drain_loop,
                                                 name="det-front-drainer",
                                                 daemon=True)
                self._drainer.start()
        return w.id

    def grow(self, count: int = 1) -> list[int]:
        """Scale the pool up by ``count`` brand-new workers via the
        transport (spawn locally / dial a standby daemon) — the
        autoscaler's scale-up action.  Returns the admitted worker ids;
        stops early when the transport has no more capacity (no spare
        daemon addresses), so the result can be shorter than asked.
        """
        admitted: list[int] = []
        prefill = (self._prefill_entries() or None) \
            if self._prefill_enabled else None
        for _ in range(int(count)):
            wid = self._reserve_wid()
            try:
                link = self._transport.dial_new(wid, prefill)
            except TransportError:
                break
            if link is None:
                break
            admitted.append(self._admit(link))
        return admitted

    def _accept_loop(self) -> None:
        """Admit ``det_serve --join`` daemons dialing into the accept
        listener.  The handshake mirrors ``SocketTransport`` with the
        direction reversed: the front speaks first — ``("hello", wid,
        cfg)`` with a freshly reserved id and the same wire config every
        other worker got — and admits on ``("ready", wid)``, so a
        dialed-in worker and a ``--connect`` worker are
        indistinguishable past the handshake."""
        srv = self._accept_srv
        while True:
            with self._lock:
                if self._closing:
                    return
            try:
                conn, addr = srv.accept()
            except socket.timeout:
                continue
            except OSError:
                return  # listener closed under us (close())
            try:
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                wid = self._reserve_wid()
                decoder = FrameDecoder()
                wire_cfg = self._wire_cfg
                if self._prefill_enabled:
                    entries = self._prefill_entries()
                    if entries:
                        # ship the live working set: the joiner warms
                        # these families before it answers ready (and
                        # is only admitted on ready)
                        wire_cfg = dict(wire_cfg)
                        wire_cfg["prefill"] = entries
                conn.sendall(encode_frame(("hello", wid, wire_cfg)))
                msg = _read_frame(conn, decoder, timeout=30.0, skip_hb=True)
                if msg is None or msg[0] != "ready" or msg[1] != wid:
                    conn.close()
                    continue
                conn.settimeout(None)
                link = SocketLink(wid, conn, (addr[0], addr[1]),
                                  self._accept_hb_timeout, decoder=decoder)
                self._admit(link, joined=True)
            except (OSError, TransportError, QueueClosedError):
                try:
                    conn.close()
                except OSError:
                    pass
                with self._lock:
                    if self._closing:
                        return

    # ------------------------------------------------------------ lifecycle
    def retire_worker(self, worker_id: int) -> None:
        """Gracefully drain one worker: it leaves the ring *now* (new
        and requeued work routes to the survivors), hands back its
        un-staged backlog for re-routing, finishes in-flight batches,
        and exits.  The planned-downscale path; ``kill_worker`` is the
        chaos path."""
        with self._lock:
            w = self._by_id[worker_id]
            if not w.alive:
                return
            self._placer.remove(worker_id)
            try:
                w.link.send(("retire",))
            except TransportError:
                pass  # already unreachable: the sweep collects it as dead

    def reconnect_worker(self, worker_id: int) -> bool:
        """Graceful rejoin after a death: ask the transport to rebuild
        the worker's link (respawn the local process / re-dial the
        daemon address) and put it back on the ring.

        The stable hash re-inserts the worker's old arc, so ownership
        after the rejoin equals ownership before the death — the same
        determinism the re-route relies on, run in reverse.  The rejoined
        worker starts empty (fresh queue, fresh plan cache) and picks up
        families on next sight exactly like a re-routed family re-plans.
        Returns ``True`` when the worker is live again; ``False`` when
        the peer stayed unreachable.
        """
        with self._lock:
            if self._closing:
                raise QueueClosedError("DetFront is closed")
            w = self._by_id[worker_id]
            if w.alive:
                return True
            if w.joined:
                return False  # live-joined peers re-join by dialing in
        try:
            link = self._transport.redial(worker_id)
        except TransportError:
            return False
        if link is None:
            return False
        with self._lock:
            if w.alive or self._closing:
                link.close()  # raced another reconnect / a close
                return w.alive
            w.link = link
            w.pending.clear()
            w.unacked.clear()
            w.alive = True
            w.clean = False
            w.timer = StepTimer()  # a fresh peer earns a fresh EMA
            self._placer.add(worker_id)
            # _drained belongs to the response cv (pollers read it under
            # _resp_cv); nest it inside _lock in the established
            # lock -> resp_cv order (same as _drain_loop_inner)
            with self._resp_cv:
                restart = self._drained  # total loss had ended the stream
                if restart:
                    self._drained = False
            if restart:
                self._drainer = threading.Thread(target=self._drain_loop,
                                                 name="det-front-drainer",
                                                 daemon=True)
                self._drainer.start()
        return True

    def kill_worker(self, worker_id: int) -> None:
        """Chaos/test hook: make a worker unreachable *now* (SIGKILL for
        a local process, a torn connection for a socket peer).  The
        drainer detects the death, delivers whatever responses survived
        in flight, and re-routes the rest."""
        self._by_id[worker_id].link.kill()

    def close(self, timeout: float | None = None) -> None:
        """Idempotent shutdown: stop every worker (each drains its
        accepted backlog), join the drainer and the links, and fail
        any future that still has no response."""
        with self._lock:
            first = not self._closing
            self._closing = True
            alive = [w for w in self._workers if w.alive]
        if first:
            if self._watchdog is not None:
                self._watchdog.stop()
            if self._accept_srv is not None:
                try:
                    self._accept_srv.close()  # accept() raises, loop exits
                except OSError:
                    pass
            for w in alive:
                try:
                    w.link.send(("stop",))
                except TransportError:
                    pass
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5)
        self._drainer.join(timeout=timeout)
        for w in self._workers:
            w.link.join(timeout=10)
            w.link.close()
        leftovers: list[_FrontRequest] = []
        with self._lock:
            for w in self._workers:
                leftovers.extend(w.pending.values())
                w.pending.clear()
        if leftovers:
            exc = QueueClosedError(
                f"DetFront closed with {len(leftovers)} unresolved requests")
            with self._resp_cv:
                self._responses.extend((r.seq, exc) for r in leftovers)
            for r in leftovers:
                self._resolve(r.future, exc=exc)
        with self._resp_cv:
            self._resp_cv.notify_all()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
