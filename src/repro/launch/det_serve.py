"""Determinant service driver: drain a queue of heterogeneous matrices
through the shape-bucketed batched Radic evaluator.

Requests arrive as arbitrary (m_i, n_i) matrices.  The batcher groups
them by exact shape (one bucket = one C(n, m) rank space = one Pascal
table = one compiled program), pads each bucket's batch dim up to a
power of two (bounded by ``--max-batch``) so at most log2(max_batch)
distinct batch shapes ever hit the jit cache per bucket, and evaluates
every bucket with :func:`repro.core.radic_det_batched` — one dispatch
per padded group instead of one per matrix.  Zero-padding is sound:
``det(0) = 0`` and padded rows are sliced off before results are
returned in arrival order.

  PYTHONPATH=src python -m repro.launch.det_serve --num 64 \
      --max-m 4 --max-n 10 --backend jnp --verify
"""

from __future__ import annotations

import argparse
import time
from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comb, radic_det_batched

__all__ = ["bucket_by_shape", "pad_capacity", "drain_queue", "main"]


def bucket_by_shape(mats) -> dict[tuple[int, int], list[int]]:
    """Queue indices grouped by exact (m, n) shape, shapes sorted."""
    buckets: dict[tuple[int, int], list[int]] = defaultdict(list)
    for i, A in enumerate(mats):
        shp = np.shape(A)
        if len(shp) != 2:
            raise ValueError(f"request {i} is not a matrix: shape {shp}")
        buckets[tuple(shp)].append(i)
    return dict(sorted(buckets.items()))


def pad_capacity(k: int, max_batch: int) -> int:
    """Smallest power of two >= k, capped at ``max_batch``."""
    cap = 1
    while cap < min(k, max_batch):
        cap *= 2
    return min(cap, max_batch)


def drain_queue(mats, *, chunk: int = 2048, backend: str = "jnp",
                max_batch: int = 64, mesh=None, batch_axis=None,
                dtype=np.float32):
    """Evaluate every queued matrix; returns ``(dets, stats)``.

    ``dets`` is a list of floats in arrival order.  ``stats`` maps each
    (m, n) bucket to a dict with ``count`` (matrices), ``dispatches``
    (device round-trips), ``ranks`` (minors evaluated, excluding
    padding), ``wall_s``, ``mats_per_s`` and ``ranks_per_s``.
    """
    out: list[float | None] = [None] * len(mats)
    stats: dict[tuple[int, int], dict] = {}
    for (m, n), idxs in bucket_by_shape(mats).items():
        t0 = time.perf_counter()
        dispatches = 0
        for base in range(0, len(idxs), max_batch):
            grp = idxs[base:base + max_batch]
            cap = pad_capacity(len(grp), max_batch)
            stack = np.zeros((cap, m, n), dtype=dtype)
            for j, i in enumerate(grp):
                stack[j] = np.asarray(mats[i], dtype=dtype)
            dets = radic_det_batched(jnp.asarray(stack), chunk=chunk,
                                     backend=backend, mesh=mesh,
                                     batch_axis=batch_axis)
            dets = np.asarray(jax.block_until_ready(dets))
            dispatches += 1
            for j, i in enumerate(grp):
                out[i] = float(dets[j])
        wall = time.perf_counter() - t0
        ranks = comb(n, m) * len(idxs) if m <= n else 0
        stats[(m, n)] = {
            "count": len(idxs),
            "dispatches": dispatches,
            "ranks": ranks,
            "wall_s": wall,
            "mats_per_s": len(idxs) / wall if wall > 0 else float("inf"),
            "ranks_per_s": ranks / wall if wall > 0 else float("inf"),
        }
    return out, stats


def _random_queue(num: int, max_m: int, max_n: int, seed: int):
    rng = np.random.default_rng(seed)
    mats = []
    for _ in range(num):
        m = int(rng.integers(1, max_m + 1))
        n = int(rng.integers(m, max_n + 1))
        mats.append(rng.normal(size=(m, n)).astype(np.float32))
    return mats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--num", type=int, default=64,
                    help="queued requests to synthesize")
    ap.add_argument("--max-m", type=int, default=4)
    ap.add_argument("--max-n", type=int, default=10)
    ap.add_argument("--chunk", type=int, default=2048)
    ap.add_argument("--backend", choices=("jnp", "pallas"), default="jnp")
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verify", action="store_true",
                    help="cross-check every result against the exact oracle")
    args = ap.parse_args(argv)

    mats = _random_queue(args.num, args.max_m, args.max_n, args.seed)
    # warm pass compiles every (bucket shape, padded batch) program so the
    # reported drain is steady-state serving, not compile time
    drain_queue(mats, chunk=args.chunk, backend=args.backend,
                max_batch=args.max_batch)
    dets, stats = drain_queue(mats, chunk=args.chunk, backend=args.backend,
                              max_batch=args.max_batch)

    print(f"# det_serve: {args.num} requests, {len(stats)} shape buckets, "
          f"backend={args.backend}")
    print("bucket_m,bucket_n,count,dispatches,ranks,wall_s,"
          "mats_per_s,ranks_per_s")
    for (m, n), s in stats.items():
        print(f"{m},{n},{s['count']},{s['dispatches']},{s['ranks']},"
              f"{s['wall_s']:.4f},{s['mats_per_s']:.1f},"
              f"{s['ranks_per_s']:.3e}")
    total_wall = sum(s["wall_s"] for s in stats.values())
    print(f"total,{args.num} mats,{total_wall:.4f}s,"
          f"{args.num / total_wall:.1f} mats/s")

    if args.verify:
        from repro.core import radic_det_oracle
        worst = 0.0
        for A, got in zip(mats, dets):
            want = radic_det_oracle(np.asarray(A))
            worst = max(worst, abs(got - want) / max(1.0, abs(want)))
        print(f"verify: worst rel err {worst:.2e}")
        assert worst <= 2e-3, worst
    return dets, stats


if __name__ == "__main__":
    main()
