"""Determinant serving CLI: drive the async pipelined
:class:`repro.launch.det_queue.DetQueue` (default), the multi-worker
:class:`repro.launch.det_front.DetFront` (``--workers N``) or the
synchronous :func:`drain_queue` reference over a queue of heterogeneous
matrices.

Requests are arbitrary (m_i, n_i) matrices.  All paths group them by
shape (one bucket = one C(n, m) rank space = one Pascal table = one
compiled program), pad each bucket's batch dim (bounded by
``--max-batch``) and evaluate buckets with
:func:`repro.core.radic_det_batched` — one dispatch per padded group
instead of one per matrix.  Zero-padding is sound: ``det(0) = 0`` and
padded rows are sliced off before results are returned in arrival
order.  The async path additionally overlaps host staging with device
execution and re-buckets dynamically (DESIGN_SERVE.md); the front
shards the shape buckets over workers, routing by canonical plan key
(DESIGN_FRONT.md) behind a pluggable transport (``launch/transport.py``).

  PYTHONPATH=src python -m repro.launch.det_serve --num 64 \
      --max-m 4 --max-n 10 --backend jnp --verify
  PYTHONPATH=src python -m repro.launch.det_serve --num 256 --sync
  PYTHONPATH=src python -m repro.launch.det_serve --num 256 --workers 2

A *multi-host* pool is two shell commands — start one worker daemon per
host, then point a front at them:

  host-a$ PYTHONPATH=src python -m repro.launch.det_serve \
      --listen 0.0.0.0:7341
  host-b$ PYTHONPATH=src python -m repro.launch.det_serve \
      --num 256 --connect host-a:7341,host-c:7341

The daemon is configuration-free: the front's ``--connect`` handshake
ships the full serving config (policy, dtype, admission control), so
routing and bucketing can never disagree across hosts.  Peer death is
detected by heartbeat deadline + per-batch acks and the front re-routes
deterministically (DESIGN_FRONT.md has the protocol spec and failure
semantics table).

The pool is *elastic* (DESIGN_FRONT.md, "Dynamic membership"): a front
started with ``--accept HOST:PORT`` admits workers that dial in later
(``det_serve --join front-host:PORT`` — same hello/ready handshake, so
late joiners get the same config), and ``--autoscale MAX`` runs the SLO
controller from ``launch/autoscale.py`` that grows/retires workers
between 1 and MAX against the front's live stats.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comb, radic_det_batched
from repro.launch.det_queue import (BucketPolicy, DetQueue, LoadShedError,
                                    bucket_by_shape, pad_capacity)

__all__ = ["bucket_by_shape", "pad_capacity", "drain_queue", "main"]


def drain_queue(mats, *, chunk: int = 2048, backend: str = "jnp",
                max_batch: int = 64, mesh=None, batch_axis=None,
                dtype=np.float32):
    """Synchronous reference: evaluate every queued matrix in the calling
    thread; returns ``(dets, stats)``.

    Stage → dispatch → block, one group at a time — the baseline the
    pipelined :class:`DetQueue` is benchmarked against
    (``benchmarks/perf_serve.py``).  ``dets`` is a list of floats in
    arrival order.  ``stats`` maps each (m, n) bucket to a dict with
    ``count`` (matrices), ``dispatches`` (device round-trips), ``ranks``
    (minors evaluated, excluding padding), ``wall_s``, ``mats_per_s``
    and ``ranks_per_s``.
    """
    out: list[float | None] = [None] * len(mats)
    stats: dict[tuple[int, int], dict] = {}
    for (m, n), idxs in bucket_by_shape(mats).items():
        t0 = time.perf_counter()
        dispatches = 0
        for base in range(0, len(idxs), max_batch):
            grp = idxs[base:base + max_batch]
            cap = pad_capacity(len(grp), max_batch)
            stack = np.zeros((cap, m, n), dtype=dtype)
            for j, i in enumerate(grp):
                stack[j] = np.asarray(mats[i], dtype=dtype)
            dets = radic_det_batched(jnp.asarray(stack), chunk=chunk,
                                     backend=backend, mesh=mesh,
                                     batch_axis=batch_axis)
            dets = np.asarray(jax.block_until_ready(dets))
            dispatches += 1
            for j, i in enumerate(grp):
                out[i] = float(dets[j])
        wall = time.perf_counter() - t0
        ranks = comb(n, m) * len(idxs) if m <= n else 0
        stats[(m, n)] = {
            "count": len(idxs),
            "dispatches": dispatches,
            "ranks": ranks,
            "wall_s": wall,
            "mats_per_s": len(idxs) / wall if wall > 0 else float("inf"),
            "ranks_per_s": ranks / wall if wall > 0 else float("inf"),
        }
    return out, stats


def _serve_tolerating_sheds(q, mats, grads=None):
    """Submit-all + wait-all like ``DetQueue.serve``, but a shed request
    yields ``None`` instead of raising — with ``--max-pending`` a
    synthetic burst larger than the bound sheds by design, and the CLI
    should report that, not crash on it.  Works on anything with the
    queue surface (``DetQueue`` and ``DetFront`` alike).  ``grads`` is
    the per-request ``(grad, cotangent)`` list both surfaces accept;
    grad requests resolve to (m, n) ndarrays instead of floats."""
    futs = q.submit_many(mats) if grads is None \
        else q.submit_many(mats, grads)
    dets = []
    for f in futs:
        try:
            dets.append(f.result())
        except LoadShedError:
            dets.append(None)
    q.poll(timeout=0)
    return dets


def _serve_front(front, mats, label: str, num: int, backend: str,
                 grads=None):
    """Warm + timed pass through any DetFront, then the front report
    (shared by ``--workers`` and ``--connect``); returns
    ``(dets, stats, wall)``."""
    _serve_tolerating_sheds(front, mats, grads)  # warm: compile programs
    front.reset_stats()  # report the timed pass only
    t0 = time.perf_counter()
    dets = _serve_tolerating_sheds(front, mats, grads)
    wall = time.perf_counter() - t0
    stats = front.snapshot()
    f, tot = stats["front"], stats["total"]
    print(f"# det_serve[{label}]: {num} requests, backend={backend}")
    print(f"front: workers={f['workers_alive']}/{f['workers_total']} "
          f"rerouted={f['rerouted']} worker_deaths={f['worker_deaths']} "
          f"shed={f['shed']} errors={f['errors']} "
          f"degraded={f['degraded']} joined={f['joined']} "
          f"stragglers_drained={f['stragglers_drained']}")
    print(f"total: batches={tot['batches']} "
          f"dispatches={tot['dispatches']} "
          f"merged_requests={tot['merged_requests']} "
          f"padded_slots={tot['padded_slots']} "
          f"backlog_peak={tot['backlog_peak']} "
          f"plan_cache={tot['plan_cache']['size']} "
          f"(hits={tot['plan_cache']['hits']} "
          f"misses={tot['plan_cache']['misses']})")
    print("worker,routed,completed,batches,shed,backlog_peak,plans")
    for wid, snap in sorted(stats["workers"].items()):
        print(f"{wid},{f['routed'].get(wid, 0)},{snap['completed']},"
              f"{snap['batches']},{snap['shed']},"
              f"{snap['backlog_peak']},{snap['plan_cache']['size']}")
    print("bucket_m,bucket_n,count,batches,ranks,mean_wait_s")
    for (m, n), b in sorted(tot["buckets"].items()):
        print(f"{m},{n},{b['count']},{b['batches']},{b['ranks']},"
              f"{b['wait_s'] / max(1, b['count']):.4f}")
    return dets, stats, wall


def _serve_scaled(front, mats, label: str, num: int, backend: str,
                  autoscale_max: int, grads=None):
    """``_serve_front``, optionally under the SLO autoscaler.

    CLI runs are seconds long, so the controller gets a fast cadence and
    short cooldown here; long-lived deployments should keep the
    :class:`~repro.launch.autoscale.AutoscalePolicy` defaults."""
    if not autoscale_max:
        return _serve_front(front, mats, label, num, backend, grads)
    from repro.launch.autoscale import Autoscaler
    with Autoscaler(front, min_workers=1, max_workers=autoscale_max,
                    interval_s=0.25, cooldown_s=2.0) as scaler:
        out = _serve_front(front, mats, f"{label}+autoscale{autoscale_max}",
                           num, backend, grads)
    print(f"autoscale: up={scaler.scaled_up} down={scaler.scaled_down} "
          f"stalls={scaler.stalls}")
    return out


def _random_queue(num: int, max_m: int, max_n: int, seed: int):
    rng = np.random.default_rng(seed)
    mats = []
    for _ in range(num):
        m = int(rng.integers(1, max_m + 1))
        n = int(rng.integers(m, max_n + 1))
        mats.append(rng.normal(size=(m, n)).astype(np.float32))
    return mats


def main(argv=None):
    ap = argparse.ArgumentParser(
        epilog="multi-host recipe: start `--listen 0.0.0.0:7341` on every "
               "worker host, then run the front with "
               "`--connect hostA:7341,hostB:7341` — the front's handshake "
               "ships the serving config, so daemons take no tuning flags; "
               "see DESIGN_FRONT.md for the wire protocol and failure "
               "semantics.  Single-host fast path: `--workers N --shm` "
               "moves matrix payloads into a per-worker shared-memory ring "
               "(zero pickling of matrix bytes, bit-identical results; "
               "DESIGN_FRONT.md §shm ring protocol), and launching through "
               "`tools/launch_env.sh` preloads tcmalloc and pins the XLA "
               "host-device count for multi-device CPU runs.  "
               "`--plan-store DIR` makes compiles survive restarts: plan "
               "artifacts persist under DIR, the next run restores instead "
               "of recompiling, and workers joining via --join are prefilled "
               "with the front's live plan families before admission "
               "(DESIGN_PERSIST.md).")
    ap.add_argument("--num", type=int, default=64,
                    help="queued requests to synthesize")
    ap.add_argument("--max-m", type=int, default=4)
    ap.add_argument("--max-n", type=int, default=10)
    ap.add_argument("--chunk", type=int, default=2048)
    ap.add_argument("--backend", choices=("jnp", "pallas"), default="jnp")
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sync", action="store_true",
                    help="use the synchronous drain_queue reference")
    ap.add_argument("--workers", type=int, default=0,
                    help="serve through the multi-worker DetFront with N "
                         "worker processes (0 = in-process DetQueue)")
    ap.add_argument("--shm", action="store_true",
                    help="--workers: carry matrix payloads over a per-"
                         "worker shared-memory ring instead of the pickled "
                         "queue (same-host only, bit-identical results)")
    ap.add_argument("--listen", type=str, default="",
                    help="run as a worker daemon on HOST:PORT instead of "
                         "serving a synthetic queue (the front's --connect "
                         "handshake ships the config; combine with "
                         "--serve-once for tests)")
    ap.add_argument("--serve-once", action="store_true",
                    help="with --listen: exit after the first front "
                         "session ends")
    ap.add_argument("--join", type=str, default="",
                    help="run as a worker daemon that dials INTO a running "
                         "front's --accept listener at HOST:PORT (live "
                         "join: same handshake as --listen, direction "
                         "reversed; exits when the front session ends)")
    ap.add_argument("--accept", type=str, default="",
                    help="--connect/--workers: also listen on HOST:PORT "
                         "for workers that dial in later with --join "
                         "(port 0 = ephemeral; the bound address is in "
                         "snapshot()['front']['accept_address'])")
    ap.add_argument("--autoscale", type=int, default=0,
                    help="--connect/--workers: run the SLO autoscaler, "
                         "growing/retiring workers between 1 and N "
                         "(0 = static pool; see launch/autoscale.py)")
    ap.add_argument("--plan-store", type=str, default="", metavar="DIR",
                    help="persist compiled DetEngine plans under DIR and "
                         "restore them on the next run (plan-cache misses "
                         "consult the store before compiling; writes are "
                         "async and never block dispatch; see "
                         "DESIGN_PERSIST.md)")
    ap.add_argument("--prefill", action="store_true",
                    help="--connect/--workers: ship joining workers the "
                         "front's live plan families in the join handshake "
                         "so they warm up (store first, compile second) "
                         "before admission (on by default when --plan-store "
                         "is set)")
    ap.add_argument("--connect", type=str, default="",
                    help="serve through a DetFront over remote worker "
                         "daemons: comma-separated host:port list, one "
                         "address per worker (see --listen)")
    ap.add_argument("--heartbeat", type=float, default=1.0,
                    help="--connect: worker heartbeat cadence in seconds "
                         "(a peer silent for 5 beats is declared dead)")
    ap.add_argument("--ack-timeout", type=float, default=0.0,
                    help="--connect/--workers: declare a worker dead when "
                         "a batch stays unacknowledged this long "
                         "(0 = disabled; bounds frame loss, not compute)")
    ap.add_argument("--policy", choices=("auto", "merge", "never"),
                    default="auto", help="re-bucketing mode (async path)")
    ap.add_argument("--max-pending", type=int, default=0,
                    help="admission-control backlog bound for the async "
                         "path (0 = unbounded; shed requests raise "
                         "LoadShedError on their futures)")
    ap.add_argument("--grad-frac", type=float, default=0.0,
                    help="fraction of requests submitted as gradient "
                         "requests (cotangent 1.0): their futures resolve "
                         "to the (m, n) ndarray d(det)/dA instead of a "
                         "float — async and front paths only "
                         "(DESIGN_GRAD.md)")
    ap.add_argument("--verify", action="store_true",
                    help="cross-check every result against the exact "
                         "oracle (gradient requests against jax.grad of "
                         "the flat evaluator)")
    args = ap.parse_args(argv)
    if not 0.0 <= args.grad_frac <= 1.0:
        ap.error("--grad-frac must be in [0, 1]")
    if args.grad_frac > 0 and args.sync:
        ap.error("--grad-frac needs the async or front path (drop --sync)")

    if args.listen:
        # worker daemon mode: no synthetic queue, no report — just a
        # DetQueue+DetEngine behind a socket, config shipped by the front
        from repro.launch.transport import parse_hostport, run_worker_server
        host, port = parse_hostport(args.listen)
        run_worker_server(host, port, serve_once=args.serve_once)
        return None, None

    if args.join:
        # live-join daemon mode: dial a running front's --accept listener
        # and serve that one session (config still ships front→worker)
        from repro.launch.transport import run_worker_client
        run_worker_client(args.join)
        return None, None

    mats = _random_queue(args.num, args.max_m, args.max_n, args.seed)
    grads = None
    if args.grad_frac > 0:
        # seed-derived, so the same command line always submits the same
        # value/grad mix (the verify leg depends on it)
        grng = np.random.default_rng(args.seed + 1)
        grads = [(bool(grng.random() < args.grad_frac), 1.0) for _ in mats]

    if args.sync:
        # warm pass compiles every (bucket shape, padded batch) program so
        # the reported drain is steady-state serving, not compile time
        drain_queue(mats, chunk=args.chunk, backend=args.backend,
                    max_batch=args.max_batch)
        t0 = time.perf_counter()
        dets, stats = drain_queue(mats, chunk=args.chunk,
                                  backend=args.backend,
                                  max_batch=args.max_batch)
        wall = time.perf_counter() - t0
        print(f"# det_serve[sync]: {args.num} requests, {len(stats)} shape "
              f"buckets, backend={args.backend}")
        print("bucket_m,bucket_n,count,dispatches,ranks,wall_s,"
              "mats_per_s,ranks_per_s")
        for (m, n), s in stats.items():
            print(f"{m},{n},{s['count']},{s['dispatches']},{s['ranks']},"
                  f"{s['wall_s']:.4f},{s['mats_per_s']:.1f},"
                  f"{s['ranks_per_s']:.3e}")
    elif args.connect:
        from repro.launch.det_front import DetFront
        from repro.launch.transport import SocketTransport
        addrs = [a.strip() for a in args.connect.split(",") if a.strip()]
        policy = BucketPolicy(max_batch=args.max_batch, mode=args.policy)
        transport = SocketTransport(addrs, heartbeat_s=args.heartbeat)
        with DetFront(transport=transport, chunk=args.chunk,
                      backend=args.backend, policy=policy,
                      max_pending=args.max_pending or None,
                      ack_timeout_s=args.ack_timeout or None,
                      accept=args.accept or None,
                      persist_dir=args.plan_store or None,
                      prefill=args.prefill or None) as front:
            dets, stats, wall = _serve_scaled(
                front, mats, f"front x{len(addrs)}@socket/{args.policy}",
                args.num, args.backend, args.autoscale, grads)
    elif args.workers > 0:
        from repro.launch.det_front import DetFront
        policy = BucketPolicy(max_batch=args.max_batch, mode=args.policy)
        wire = "shm" if args.shm else "local"
        with DetFront(workers=args.workers, chunk=args.chunk,
                      backend=args.backend, policy=policy,
                      max_pending=args.max_pending or None,
                      ack_timeout_s=args.ack_timeout or None,
                      accept=args.accept or None, shm=args.shm,
                      persist_dir=args.plan_store or None,
                      prefill=args.prefill or None) as front:
            dets, stats, wall = _serve_scaled(
                front, mats, f"front x{args.workers}@{wire}/{args.policy}",
                args.num, args.backend, args.autoscale, grads)
    else:
        policy = BucketPolicy(max_batch=args.max_batch, mode=args.policy)
        with DetQueue(chunk=args.chunk, backend=args.backend, policy=policy,
                      max_pending=args.max_pending or None,
                      persist_dir=args.plan_store or None) as q:
            _serve_tolerating_sheds(q, mats, grads)  # warm: compile programs
            q.reset_stats()  # report the timed pass only, not warm+compile
            t0 = time.perf_counter()
            dets = _serve_tolerating_sheds(q, mats, grads)
            wall = time.perf_counter() - t0
            stats = q.snapshot()
        print(f"# det_serve[async/{args.policy}]: {args.num} requests, "
              f"backend={args.backend}")
        print(f"batches={stats['batches']} dispatches={stats['dispatches']} "
              f"merged_requests={stats['merged_requests']} "
              f"padded_slots={stats['padded_slots']} "
              f"shed={stats['shed']} backlog_peak={stats['backlog_peak']} "
              f"plan_cache={stats['plan_cache']['size']}/"
              f"{stats['plan_cache']['max_plans']}")
        print("bucket_m,bucket_n,count,batches,ranks,mean_wait_s")
        for (m, n), b in sorted(stats["buckets"].items()):
            print(f"{m},{n},{b['count']},{b['batches']},{b['ranks']},"
                  f"{b['wait_s'] / max(1, b['count']):.4f}")
    print(f"total,{args.num} mats,{wall:.4f}s,{args.num / wall:.1f} mats/s")

    if args.verify:
        from repro.core import radic_det, radic_det_oracle
        worst = worst_g = 0.0
        for i, (A, got) in enumerate(zip(mats, dets)):
            if got is None:  # shed under --max-pending: nothing to check
                continue
            if grads is not None and grads[i][0]:
                # gradient request: reference is jax.grad through the
                # differentiable evaluator (a different code path —
                # direct unbatched eval vs the staged/padded batch)
                want_g = np.asarray(jax.grad(radic_det)(jnp.asarray(A)))
                err = np.max(np.abs(np.asarray(got) - want_g))
                worst_g = max(worst_g, err / max(1.0, np.max(np.abs(want_g))))
                continue
            want = radic_det_oracle(np.asarray(A))
            worst = max(worst, abs(got - want) / max(1.0, abs(want)))
        print(f"verify: worst rel err {worst:.2e}"
              + (f", worst grad rel err {worst_g:.2e}"
                 if grads is not None else ""))
        assert worst <= 2e-3, worst
        assert worst_g <= 2e-3, worst_g
    return dets, stats


if __name__ == "__main__":
    main()
