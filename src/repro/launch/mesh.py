"""Production meshes.

Functions, not module-level constants, so importing this module never
touches jax device state (per the dry-run contract)."""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_rules"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_rules(cfg, mesh, *, log_fallbacks: bool = False):
    """ShardingRules for a model config on a mesh (FSDP-over-pod for the
    405B-class configs, see ModelConfig.fsdp_over_pod)."""
    from repro.parallel.sharding import (ACT_RULES_LARGE, ACT_RULES_SMALL,
                                         PARAM_RULES_LARGE,
                                         PARAM_RULES_SMALL, ShardingRules)
    large = getattr(cfg, "fsdp_over_pod", False)
    act = dict(ACT_RULES_LARGE if large else ACT_RULES_SMALL)
    if getattr(cfg, "seq_shard", False):
        act["seq"] = "model"  # sequence-parallel residual activations
    return ShardingRules(
        mesh=mesh,
        act=act,
        params=PARAM_RULES_LARGE if large else PARAM_RULES_SMALL,
        log_fallbacks=log_fallbacks,
    )
