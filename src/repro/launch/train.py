"""End-to-end training driver (CPU-runnable at smoke scale, mesh-ready).

Wires every substrate together: data pipeline → sharded train step →
checkpoint/restart → watchdog + straggler detection → elastic mesh choice.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 200 --ckpt /tmp/ckpt
  # kill it mid-run, re-run the same command: resumes from the last step.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.registry import get_config
from repro.data import DataConfig, Prefetcher, SyntheticLMData
from repro.launch.mesh import make_rules
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, warmup_cosine
from repro.parallel.sharding import tree_param_shardings, use_rules
from repro.runtime import StepTimer, Watchdog, build_mesh, choose_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--max-model-axis", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=warmup_cosine(args.lr, 20, args.steps),
                          weight_decay=0.01)

    # ---- elastic mesh over whatever devices are healthy ----
    plan = choose_mesh(len(jax.devices()), max_model=args.max_model_axis)
    mesh = build_mesh(plan)
    rules = make_rules(cfg, mesh)
    print(f"mesh: {plan.shape} {plan.axis_names} "
          f"({plan.n_devices} devices)")

    data = SyntheticLMData(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch))

    with use_rules(rules), mesh:
        params = model.init(jax.random.PRNGKey(0))
        opt_state = adamw_init(params, opt_cfg)
        psh = tree_param_shardings(params, model.logical_axes(), rules)
        params = jax.tree.map(jax.device_put, params, psh)
        step_fn = jax.jit(make_train_step(model, opt_cfg),
                          donate_argnums=(0, 1))

        start = 0
        mgr = None
        if args.ckpt:
            mgr = CheckpointManager(args.ckpt)
            restored = mgr.restore({"params": params, "opt": opt_state})
            if restored is not None:
                start, tree = restored
                params = jax.tree.map(jax.device_put, tree["params"], psh)
                opt_state = tree["opt"]
                print(f"resumed from step {start}")

        wd = Watchdog(timeout_s=300.0,
                      on_stall=lambda: print("WATCHDOG: step stalled"))
        wd.start()
        timer = StepTimer()
        fetch = Prefetcher(data, start_step=start)
        losses = []
        try:
            for _ in range(start, args.steps):
                step_i, batch = fetch.next()
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                t0 = time.time()
                params, opt_state, metrics = step_fn(params, opt_state,
                                                     batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                wd.beat()
                if timer.record(step_i, dt):
                    print(f"  straggler step {step_i}: {dt:.2f}s "
                          f"(ema {timer.ema:.2f}s)")
                losses.append(loss)
                if step_i % args.log_every == 0:
                    print(f"step {step_i:5d} loss {loss:.4f} "
                          f"gnorm {float(metrics['grad_norm']):.3f} "
                          f"lr {float(metrics['lr']):.2e} {dt:.2f}s")
                if mgr and (step_i + 1) % args.ckpt_every == 0:
                    mgr.save_async(step_i + 1, {"params": params,
                                                "opt": opt_state})
            if mgr:
                mgr.save(args.steps, {"params": params, "opt": opt_state})
        finally:
            fetch.close()
            wd.stop()
        print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}); "
              f"stragglers={timer.stragglers}")
        return losses


if __name__ == "__main__":
    main()
