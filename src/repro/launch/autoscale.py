"""SLO autoscaler for the determinant serving front.

The paper's O(n²) bound holds when the C(n, m) minor enumeration is
spread over however many workers are *currently* healthy — so the pool
size must track load, not the launch-time guess.  This module closes
that loop: a small controller samples the stats the serving tier
already emits (:meth:`DetFront.snapshot` — per-worker front-side
backlog, completion-latency EMAs, shed counters) and adds or retires
workers against an SLO target.

The controller is deliberately boring — a thresholded hysteresis loop,
no model, no prediction — because every actuator it drives is already
deterministic and safe:

* **scale-up** is :meth:`DetFront.grow` (the transport spawns a local
  worker or dials a standby daemon; a ``det_serve --join`` daemon
  dialing the front's ``--accept`` listener arrives through the same
  admission path).  Admission is atomic under the router lock and the
  sticky placer keeps every already-assigned plan family on the worker
  that compiled it, so a join never moves in-flight work and results
  stay bit-identical (DESIGN_FRONT.md, "Dynamic membership").
* **scale-down** is :meth:`DetFront.retire_worker` — the graceful
  drain: the victim leaves the ring first, hands back its un-staged
  backlog for re-routing, and finishes in-flight batches.

Hysteresis, so the pool never flaps (the constants live in
:class:`AutoscalePolicy` and are documented in DESIGN_FRONT.md):
a scale-up needs ``up_ticks`` *consecutive* breach observations, a
scale-down needs ``idle_ticks`` consecutive idle observations, and any
membership action opens a ``cooldown_s`` window in which no further
action fires (the survivors' latency EMAs and the placer's load vector
need time to absorb a membership change before the next verdict).

The loop thread is guarded by a :class:`~repro.runtime.watchdog
.Watchdog` — a controller wedged inside ``snapshot()`` (a degraded
pool can make it wait out its timeout) surfaces as a counted stall,
not a silently dead autoscaler.
"""

from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass, replace

from repro.launch.det_queue import QueueClosedError
from repro.runtime.elastic import choose_mesh
from repro.runtime.watchdog import Watchdog

__all__ = ["Autoscaler", "AutoscalePolicy", "default_max_workers"]


def default_max_workers() -> int:
    """The host's physical worker ceiling: the largest power-of-two
    worker count the cores support (``choose_mesh``'s grid rule with
    the model axis pinned to 1 — one serving worker is one data-
    parallel slot; lost cores rarely leave a perfect grid)."""
    return choose_mesh(os.cpu_count() or 1, max_model=1).n_devices


@dataclass(frozen=True)
class AutoscalePolicy:
    """Thresholds and hysteresis constants (see DESIGN_FRONT.md).

    ``backlog_high`` is mean front-side pending per alive worker;
    ``slo_latency_s`` bounds any worker's completion-latency EMA
    (None disables the latency trigger); a tick is a *breach* when
    either bound is exceeded or requests were shed since the last
    tick, and *idle* when nothing is pending, nothing was submitted
    and nothing was shed since the last tick.

    Plan-cache temperature (DESIGN_PERSIST.md): a worker is *cold*
    while its combined engine+store hit rate
    ``(hits + store_hits) / (hits + misses)`` sits below
    ``cold_hit_rate`` — i.e. it is still paying compiles that neither
    the LRU cache nor the plan store absorbed.  Cold workers are
    reported to the front (:meth:`DetFront.mark_cold_workers`), which
    shields them from the straggler sweep: a joiner's warm-up compile
    latency must never read as slowness and get it drained right after
    arrival.  A warm-started joiner (prefilled from the store) scores
    ``store_hits ≈ misses`` and is hot from its first tick — which is
    why scale-out through a populated store adds capacity without an
    entry cliff.  ``cold_grace_requests`` bounds the shield: past that
    many plan-cache lookups a worker has had its warm-up and competes
    on latency like everyone else.
    """
    min_workers: int = 1
    max_workers: int = 2
    backlog_high: float = 8.0
    slo_latency_s: float | None = None
    up_ticks: int = 2
    idle_ticks: int = 4
    cooldown_s: float = 10.0
    interval_s: float = 1.0
    cold_hit_rate: float = 0.5
    cold_grace_requests: int = 64

    def __post_init__(self):
        if self.min_workers < 1:
            raise ValueError("min_workers must be >= 1")
        if self.max_workers < self.min_workers:
            raise ValueError("max_workers must be >= min_workers")
        if not 0.0 <= self.cold_hit_rate <= 1.0:
            raise ValueError("cold_hit_rate must be in [0, 1]")
        if self.cold_grace_requests < 0:
            raise ValueError("cold_grace_requests must be >= 0")


class Autoscaler:
    """Scale a :class:`~repro.launch.det_front.DetFront` between
    ``min_workers`` and ``max_workers`` against an SLO target.

    ``tick()`` is one observation + at most one membership action and
    is callable directly (the tests drive it with injected snapshots
    and clocks for determinism); ``start()`` runs it every
    ``interval_s`` on a daemon thread until ``stop()``.
    """

    # reprolint lock-discipline registry (see DESIGN_LINT.md): the
    # hysteresis state is shared between the loop thread, direct tick()
    # callers and the watchdog's stall callback.
    _GUARDED_BY = {
        "_breach_ticks": ("_lock",),
        "_idle_ticks": ("_lock",),
        "_last_action_t": ("_lock",),
        "_last_shed": ("_lock",),
        "_last_submitted": ("_lock",),
        "scaled_up": ("_lock",),
        "scaled_down": ("_lock",),
        "stalls": ("_lock",),
    }

    def __init__(self, front, policy: AutoscalePolicy | None = None,
                 **overrides):
        if policy is None:
            policy = AutoscalePolicy()
        if overrides:
            policy = replace(policy, **overrides)
        self.front = front
        self.policy = policy
        self._lock = threading.Lock()
        self._breach_ticks = 0
        self._idle_ticks = 0
        self._last_action_t = float("-inf")  # first action needs no cooldown
        self._last_shed: int | None = None
        self._last_submitted: int | None = None
        self.scaled_up = 0
        self.scaled_down = 0
        self.stalls = 0
        self._stop_evt = threading.Event()
        self._thread: threading.Thread | None = None
        self._wd: Watchdog | None = None

    # ------------------------------------------------------------- decision
    def _note_stall(self) -> None:
        with self._lock:
            self.stalls += 1

    def _cold_set(self, workers: dict) -> set[int]:
        """Worker ids still paying their warm-up compiles: combined
        engine+store plan-cache hit rate below ``cold_hit_rate``, with
        the shield expiring after ``cold_grace_requests`` lookups.  A
        store-prefilled joiner scores ``store_hits == misses`` (rate
        1.0) and is never cold."""
        p = self.policy
        cold: set[int] = set()
        for wid, wsnap in workers.items():
            pc = wsnap.get("plan_cache") if isinstance(wsnap, dict) else None
            if not isinstance(pc, dict):
                continue
            hits = int(pc.get("hits", 0))
            misses = int(pc.get("misses", 0))
            store_hits = int(pc.get("store_hits", 0))
            if hits + misses > p.cold_grace_requests:
                continue
            rate = (hits + store_hits) / max(1, hits + misses)
            if rate < p.cold_hit_rate:
                cold.add(int(wid))
        return cold

    @staticmethod
    def _pick_victim(front_stats: dict) -> int | None:
        """The scale-down victim: the least plan-loaded routable worker
        (ties broken by id, so the choice is deterministic)."""
        load = front_stats.get("plan_load", {})
        if not load:
            return None
        return min(load, key=lambda wid: (load[wid], wid))

    def tick(self, snap: dict | None = None, now: float | None = None) -> str:
        """One control step; returns ``"up"``, ``"down"`` or ``"hold"``.

        ``snap``/``now`` default to a live ``front.snapshot()`` and the
        monotonic clock; tests inject both.
        """
        p = self.policy
        if now is None:
            now = time.monotonic()
        if snap is None:
            snap = self.front.snapshot(timeout=max(5.0, 5 * p.interval_s))
        f = snap["front"]
        # plan-cache temperature: report cold workers before the
        # membership verdict so the front's straggler sweep never
        # confuses a joiner's warm-up compiles with slowness.  Injected
        # test snapshots may carry no per-worker section and stub
        # fronts may lack the hook — both degrade to "nobody is cold".
        mark_cold = getattr(self.front, "mark_cold_workers", None)
        if mark_cold is not None:
            mark_cold(self._cold_set(snap.get("workers") or {}))
        alive = int(f.get("workers_alive", 0))
        pending = sum(f.get("pending", {}).values())
        submitted = int(f.get("submitted", 0))
        shed = int(f.get("shed", 0))
        lat = max(f.get("latency_ema_s", {}).values(), default=0.0)

        with self._lock:
            # deltas survive a reset_stats(): a counter that went
            # backwards means the window restarted, not negative traffic
            shed_delta = (shed - self._last_shed
                          if self._last_shed is not None
                          and shed >= self._last_shed else 0)
            sub_delta = (submitted - self._last_submitted
                         if self._last_submitted is not None
                         and submitted >= self._last_submitted else 0)
            self._last_shed = shed
            self._last_submitted = submitted

            breach = (pending / max(1, alive) > p.backlog_high
                      or shed_delta > 0
                      or (p.slo_latency_s is not None
                          and lat > p.slo_latency_s))
            idle = pending == 0 and shed_delta == 0 and sub_delta == 0
            self._breach_ticks = self._breach_ticks + 1 if breach else 0
            self._idle_ticks = self._idle_ticks + 1 if idle else 0
            cooled = now - self._last_action_t >= p.cooldown_s

            action = "hold"
            if (breach and self._breach_ticks >= p.up_ticks and cooled
                    and alive < p.max_workers):
                action = "up"
            elif (idle and self._idle_ticks >= p.idle_ticks and cooled
                    and alive > p.min_workers):
                action = "down"
            if action != "hold":
                # the cooldown opens even if the actuator below falls
                # short (no spare daemon): hammering a capped transport
                # every tick is exactly the flap this window prevents
                self._last_action_t = now
                self._breach_ticks = 0
                self._idle_ticks = 0

        if action == "up":
            grown = self.front.grow(1)
            with self._lock:
                self.scaled_up += len(grown)
            if not grown:
                action = "hold"  # transport at capacity
        elif action == "down":
            victim = self._pick_victim(f)
            if victim is None:
                action = "hold"
            else:
                self.front.retire_worker(victim)
                with self._lock:
                    self.scaled_down += 1
        return action

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "Autoscaler":
        if self._thread is not None:
            return self
        self._wd = Watchdog(max(10 * self.policy.interval_s, 10.0),
                            self._note_stall).start()
        self._thread = threading.Thread(target=self._run,
                                        name="det-autoscaler", daemon=True)
        self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop_evt.wait(self.policy.interval_s):
            try:
                self.tick()
            except QueueClosedError:
                return  # front closed under us: the loop's work is done
            except RuntimeError:
                return  # no live workers / front torn down mid-tick
            finally:
                if self._wd is not None:
                    self._wd.beat()

    def stop(self, timeout: float = 10.0) -> None:
        self._stop_evt.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
        if self._wd is not None:
            self._wd.stop()

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
        return False
