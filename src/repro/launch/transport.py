"""Pluggable transport layer under the multi-worker serving front.

``DetFront`` (DESIGN_FRONT.md) routes requests by canonical plan key
over a consistent-hash ring of workers, each running one
:class:`~repro.launch.det_queue.DetQueue` + ``DetEngine``.  Routing,
bounded-load placement, re-route semantics and stats aggregation never
touch process-local state — the only part of the front that knows *how*
bytes reach a worker is the transport, and this module is that seam:

* :class:`LocalTransport` — the original single-host path: ``spawn``
  worker processes wired with an ``mp.Queue`` (requests) and a ``Pipe``
  (responses), peer death detected via the process sentinel.  Kept
  message-for-message identical to the pre-seam front, so single-host
  results stay bit-identical.
* :class:`ShmTransport` — the single-host *fast* path: the same spawn
  topology and Queue/Pipe control plane, but matrix payloads travel
  through a per-link ``multiprocessing.shared_memory`` ring buffer as
  plain ``(offset, shape, dtype)`` descriptors — no pickling of the
  matrix bytes.  Payloads that don't fit fall back to the inline
  ndarray per message, so correctness never depends on ring capacity.
  Results are bit-identical to :class:`LocalTransport` (same bytes,
  same worker code past decode); ``det_serve --shm`` selects it.
* :class:`SocketTransport` — the multi-host path: length-prefixed
  pickled frames over TCP to :func:`run_worker_server` daemons
  (``det_serve --listen host:port``), peer death detected by
  heartbeat/deadline instead of a sentinel, torn/corrupt frames
  detected by a CRC and treated as peer death so the front's existing
  deterministic re-route machinery takes over.

Both implement one interface (:class:`WorkerLink` per worker, created
by ``Transport.start``), so a multi-host pool is two shell commands::

    host-a$ python -m repro.launch.det_serve --listen 0.0.0.0:7341
    host-b$ python -m repro.launch.det_serve --num 256 \\
                --connect host-a:7341,host-c:7341

Wire protocol (DESIGN_FRONT.md has the full spec):

* **Frame**: ``magic(2B) | payload_len(4B, big-endian) | crc32(4B) |
  payload`` — payload is a pickled message tuple.  A bad magic, an
  oversized length or a CRC mismatch means the stream desynchronized
  (truncated/corrupt frame): :class:`FrameError`, peer declared dead.
* **Handshake**: the front sends ``("hello", worker_id, cfg_wire)`` and
  waits for ``("ready", worker_id)``; the daemon builds its ``DetQueue``
  from the front's :class:`WorkerConfig` (one config source — the front
  — so routing policy and bucketing policy can never disagree).
* **Requests**: ``("batch", bid, [(seq, ndarray), …])`` — ``bid`` is
  the front's batch id, acknowledged on receipt — plus the control
  messages ``("stats", token)``, ``("reset",)``, ``("retire",)``,
  ``("stop",)``.  A gradient request rides the same message as a
  ``(seq, ndarray, ct)`` triple: the determinant is scalar-valued, so
  the full cotangent payload is one float (DESIGN_GRAD.md).
* **Responses**: ``("ack", bid)`` (batch frame received, sent *before*
  evaluation so lost frames are detected on RTT scale, never compute
  scale), ``("result", seq, det)`` — ``det`` is a float for a value
  request, the (m, n) gradient ndarray for a grad request —
  ``("shed", seq, msg)``,
  ``("error", seq, type_name, msg)``, ``("stats", id, snapshot,
  token)``, ``("requeue", seq)``, ``("hb", id)`` (filtered at the link,
  never surfaced to the front) and a final ``("bye", id)``.

Messages carry only plain picklable data (ints, strings, numpy arrays,
:class:`~repro.launch.det_queue.BucketPolicy` via its ``to_wire`` dict)
— see ``tests/test_front_props.py`` for the round-trip properties and
``tests/test_transport_faults.py`` for the fault battery.
"""

from __future__ import annotations

import multiprocessing as mp
import pickle
import queue as _queue
import socket
import struct
import threading
import time
import zlib
from concurrent.futures import Future
from dataclasses import asdict, dataclass, fields

import numpy as np

from repro.launch.det_queue import BucketPolicy, LoadShedError

__all__ = ["FrameDecoder", "FrameError", "LocalTransport", "ShmRing",
           "ShmRingReader", "ShmTransport", "SocketTransport",
           "ThreadedWorkerServer", "Transport", "TransportError",
           "WorkerConfig", "WorkerLink", "encode_frame", "is_shm_descriptor",
           "parse_hostport", "run_worker_client", "run_worker_loop",
           "run_worker_server", "shm_descriptor", "spawn_worker_daemon"]


class TransportError(RuntimeError):
    """A worker link failed (send to a dead peer, handshake timeout,
    torn stream).  The front treats it as peer death and re-routes."""


class FrameError(TransportError):
    """The byte stream desynchronized: bad magic, oversized length or
    CRC mismatch — a truncated or corrupted frame.  Unrecoverable for
    the connection (framing has no resync point by design: a desynced
    peer must be declared dead, its requests re-routed)."""


# ------------------------------------------------------------------ framing
_MAGIC = b"\xd7\x4d"            # 0xD74D: "det matrix"
_HEADER = struct.Struct("!2sII")  # magic, payload length, crc32(payload)
MAX_FRAME_BYTES = 1 << 30       # 1 GiB: no sane batch is larger; a bogus
#                                 length from a desynced stream must not
#                                 look like a pending 7-exabyte recv


def encode_frame(msg) -> bytes:
    """One wire frame for one message tuple.  Refuses payloads the
    decoder would reject (> ``MAX_FRAME_BYTES``) — an oversized batch
    must fail loudly at the sender, not desync every receiver it
    touches."""
    payload = pickle.dumps(msg, protocol=pickle.HIGHEST_PROTOCOL)
    if len(payload) > MAX_FRAME_BYTES:
        raise TransportError(
            f"frame payload of {len(payload)} bytes exceeds the "
            f"{MAX_FRAME_BYTES}-byte frame limit (split the batch)")
    return _HEADER.pack(_MAGIC, len(payload), zlib.crc32(payload)) + payload


class FrameDecoder:
    """Incremental frame parser: feed arbitrary byte chunks, get whole
    messages.  Tolerates any split points (TCP is a byte stream);
    raises :class:`FrameError` on desync and stays poisoned after —
    the connection must be torn down, not resumed."""

    def __init__(self):
        self._buf = bytearray()
        self._poisoned = False

    def feed(self, data: bytes) -> list:
        if self._poisoned:
            raise FrameError("decoder already desynchronized")
        self._buf += data
        out = []
        while True:
            if len(self._buf) < _HEADER.size:
                return out
            magic, length, crc = _HEADER.unpack_from(self._buf)
            if magic != _MAGIC or length > MAX_FRAME_BYTES:
                self._poisoned = True
                raise FrameError(
                    f"frame desync: magic={magic!r} length={length}")
            end = _HEADER.size + length
            if len(self._buf) < end:
                return out
            payload = bytes(self._buf[_HEADER.size:end])
            del self._buf[:end]
            if zlib.crc32(payload) != crc:
                self._poisoned = True
                raise FrameError("frame desync: payload CRC mismatch")
            try:
                out.append(pickle.loads(payload))
            except Exception as e:  # noqa: BLE001 — torn pickle = desync
                self._poisoned = True
                raise FrameError(f"frame payload unpickle failed: {e}") \
                    from e


def parse_hostport(addr: str, *, default_host: str = "0.0.0.0") \
        -> tuple[str, int]:
    """``"host:port"`` / ``":port"`` / ``"port"`` → ``(host, port)``."""
    text = addr.strip()
    if ":" in text:
        host, _, port = text.rpartition(":")
        host = host or default_host
    else:
        host, port = default_host, text
    try:
        return host, int(port)
    except ValueError:
        raise ValueError(f"bad address {addr!r}: want host:port") from None


# ------------------------------------------------------------ worker config
@dataclass(frozen=True)
class WorkerConfig:
    """Everything a worker needs to build its DetQueue — plain picklable
    fields only, with an explicit plain-dict wire form for the socket
    handshake (mesh serving stays out of scope for remote workers — a
    mesh wants the whole host)."""
    chunk: int
    backend: str
    dtype: str
    policy: BucketPolicy
    max_pending: int | None
    plan_cache: int
    linger_s: float
    stage_depth: int | None
    pipeline_depth: int
    x64: bool
    pin_workers: bool
    # durable plan store root (DESIGN_PERSIST.md); a plain string so it
    # rides the wire dict like every other field.  Workers on other
    # hosts simply see an empty/fresh store at that path.
    persist_dir: str | None = None

    def to_wire(self) -> dict:
        d = asdict(self)
        d["policy"] = self.policy.to_wire()
        return d

    @classmethod
    def from_wire(cls, d: dict) -> "WorkerConfig":
        names = {f.name for f in fields(cls)}
        kw = {k: v for k, v in d.items() if k in names}
        kw["policy"] = BucketPolicy.from_wire(d["policy"])
        return cls(**kw)

    def make_queue(self):
        from repro.launch.det_queue import DetQueue
        return DetQueue(chunk=self.chunk, backend=self.backend,
                        dtype=np.dtype(self.dtype), policy=self.policy,
                        max_pending=self.max_pending,
                        plan_cache=self.plan_cache, linger_s=self.linger_s,
                        stage_depth=self.stage_depth,
                        pipeline_depth=self.pipeline_depth,
                        persist_dir=self.persist_dir)

    def apply_x64(self) -> None:
        """Align the process's x64 flag with the front's.  A no-op when
        they already agree (the in-thread daemons the tests use share
        the front's process and must not flip it mid-flight)."""
        import jax
        if bool(jax.config.jax_enable_x64) != self.x64:
            jax.config.update("jax_enable_x64", self.x64)


# ----------------------------------------------------------- worker side
def run_worker_loop(worker_id: int, q, recv, recv_nowait, send_raw) -> None:
    """The transport-agnostic worker service loop.

    Owns one ``DetQueue`` ``q``, consumes request messages via ``recv``
    (blocking) / ``recv_nowait`` (raises ``queue.Empty``), and reports
    every outcome through ``send_raw`` — which may raise on a dead
    front; every send is best-effort.  Greedy drain: one
    ``submit_many`` per wake, so the queue's stager sees deep
    snapshots, not a trickle.  On ``stop``/``retire`` the queue is
    closed with ``drain=True`` (every accepted request resolves first)
    and a final ``("bye", id)`` is sent.
    """
    send_lock = threading.Lock()  # completer callbacks race the main loop

    def send(msg) -> None:
        with send_lock:
            try:
                send_raw(msg)
            except (OSError, ValueError, BrokenPipeError, TransportError):
                pass  # front went away; nothing useful to do from here

    def on_done(seq: int):
        def cb(fut: Future) -> None:
            exc = fut.exception()
            if exc is None:
                val = fut.result()
                if isinstance(val, np.ndarray):
                    # a gradient result: the (m, n) cotangent pullback
                    # rides the frame as-is (ndarrays are first-class
                    # wire payloads, same as the request matrices)
                    send(("result", seq, val))
                else:
                    send(("result", seq, float(val)))
            elif isinstance(exc, LoadShedError):
                send(("shed", seq, str(exc)))
            else:
                send(("error", seq, type(exc).__name__, str(exc)))
        return cb

    def submit_pairs(pairs) -> None:
        # a pair is ``(seq, arr)`` for a value request or
        # ``(seq, arr, ct)`` for a gradient request (scalar cotangent)
        seqs: list = []
        arrs: list = []
        grads: list = []
        for pr in pairs:
            if len(pr) == 3:
                seq, arr, ct = pr
                grads.append((True, ct))
            else:
                seq, arr = pr
                grads.append((False, 1.0))
            seqs.append(seq)
            arrs.append(arr)
        try:
            futs = q.submit_many(arrs, grads)
        except Exception as e:  # noqa: BLE001 — report, keep serving
            for seq in seqs:
                send(("error", seq, type(e).__name__, str(e)))
            return
        for seq, fut in zip(seqs, futs):
            fut.add_done_callback(on_done(seq))

    try:
        retired = False
        while not retired:
            msgs = [recv()]
            while True:  # greedy drain (see docstring)
                try:
                    msgs.append(recv_nowait())
                except _queue.Empty:
                    break
            pairs: list = []
            for msg in msgs:
                kind = msg[0]
                if kind == "batch":
                    # ack on *receipt*, before any evaluation: the front
                    # bounds frame loss on ack latency (RTT + queueing),
                    # never on compute — a batch may then legitimately
                    # sit in XLA compilation for seconds
                    send(("ack", msg[1]))
                    pairs.extend(msg[2])
                    continue
                if pairs:
                    submit_pairs(pairs)
                    pairs = []
                if kind == "stop":
                    retired = True
                    break
                if kind == "retire":
                    # hand the un-staged backlog back for re-routing;
                    # in-flight work still completes before the bye
                    for r in q.drain_pending():
                        send(("requeue", r.seq))
                    retired = True
                    break
                if kind == "reset":
                    q.reset_stats()
                elif kind == "stats":
                    send(("stats", worker_id, q.snapshot(), msg[1]))
            if pairs:
                submit_pairs(pairs)
    finally:
        q.close(drain=True)   # resolves every accepted request first
        send(("bye", worker_id))


def _local_worker_main(worker_id: int, cfg: WorkerConfig, req_q, resp_conn,
                       shm_name: str | None = None, prefill=None):
    """Local worker process entry point (module-level: spawn-safe).

    With ``shm_name`` (the :class:`ShmTransport` path) the Queue/Pipe
    control plane is unchanged, but batch payloads may arrive as shm
    ring descriptors: they are resolved — copied out of the ring and
    the ring slot released — *at decode time*, before
    :func:`run_worker_loop` sees the message, so ack-on-receipt and the
    greedy drain behave identically to the inline-ndarray path.
    """
    import os

    if cfg.pin_workers and hasattr(os, "sched_setaffinity"):
        # one dedicated core per worker (round-robin): N compute-heavy
        # workers on an N-core host otherwise migrate across cores and
        # steal cycles from each other's XLA threads
        try:
            os.sched_setaffinity(0, {worker_id % (os.cpu_count() or 1)})
        except OSError:
            pass
    cfg.apply_x64()
    reader = None
    recv, recv_nowait = req_q.get, req_q.get_nowait
    if shm_name is not None:
        reader = ShmRingReader(shm_name)

        def _resolve(msg):
            if isinstance(msg, tuple) and msg and msg[0] == "batch":
                # a pair's matrix slot (index 1) may be a ring
                # descriptor; any trailing fields (a grad request's
                # scalar cotangent) pass through untouched
                pairs = [(pr[0], reader.read(pr[1])
                          if is_shm_descriptor(pr[1]) else pr[1])
                         + tuple(pr[2:]) for pr in msg[2]]
                return ("batch", msg[1], pairs)
            return msg

        def recv():
            return _resolve(req_q.get())

        def recv_nowait():
            return _resolve(req_q.get_nowait())

    q = cfg.make_queue()
    if prefill:
        # warm expected plan families (store first, compile second)
        # before consuming any request — a grown worker joins hot
        q.prefill(prefill)
    try:
        run_worker_loop(worker_id, q, recv, recv_nowait, resp_conn.send)
    finally:
        try:
            resp_conn.close()
        except OSError:
            pass
        if reader is not None:
            reader.close()


# ----------------------------------------------------------- link interface
class WorkerLink:
    """One worker as the front's drainer sees it, any transport.

    * ``send(msg)`` — deliver a request message; raises
      :class:`TransportError` if the peer is unreachable.
    * ``waitables()`` — objects for ``multiprocessing.connection.wait``
      (pipes, sockets, process sentinels: anything with a fileno).
    * ``pump()`` — drain every response message available *right now*
      without blocking; returns ``(messages, dead)`` where ``dead``
      means no further message can ever arrive (buffered messages are
      always surfaced before death is reported, so results that beat a
      crash are still delivered).
    * ``expired(now)`` — transport-level death verdicts that no
      waitable can signal (a silent peer past its heartbeat deadline).
    * ``broken`` — the link itself failed (send error, torn frame,
      ``kill()``); the front's sweep turns it into a worker death.
    * ``kill()`` — chaos hook: make the peer unreachable now.
    * ``close()`` / ``join(timeout)`` — teardown.
    """

    id: int
    broken: bool = False

    def send(self, msg) -> None:
        raise NotImplementedError

    def waitables(self) -> list:
        raise NotImplementedError

    def pump(self) -> tuple[list, bool]:
        raise NotImplementedError

    def expired(self, now: float) -> bool:
        return False

    def kill(self) -> None:
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError

    def join(self, timeout: float | None = None) -> None:
        pass

    def describe(self) -> str:
        return f"{type(self).__name__}(id={self.id})"


class Transport:
    """Factory for the front's worker links.  ``start(cfg)`` builds and
    returns one :class:`WorkerLink` per worker; the front owns the
    links from then on.  ``redial(wid)`` optionally rebuilds a dead
    worker's link (``DetFront.reconnect_worker``): a fresh peer with an
    empty queue — the stable ring re-inserts its old arc, so placement
    after a rejoin equals placement before the death.  ``dial_new(wid)``
    optionally brings up a worker that never existed (``DetFront.grow``,
    the autoscaler's scale-up path): a brand-new peer under a brand-new
    id, admitted to the ring as a live join.

    ``dial_new``'s ``prefill`` is the front's plan-family warm-start
    list — plain ``(m, n, capacity)`` tuples the new worker plans
    (store first, compile second) *before* reporting for traffic, so a
    grown worker doesn't enter the ring cold (DESIGN_PERSIST.md)."""

    def start(self, cfg: WorkerConfig) -> list[WorkerLink]:
        raise NotImplementedError

    def redial(self, wid: int) -> WorkerLink | None:
        return None  # transports without a rejoin story

    def dial_new(self, wid: int, prefill=None) -> WorkerLink | None:
        return None  # transports without a scale-out story


# ------------------------------------------------------------ local (spawn)
class LocalLink(WorkerLink):
    """Today's spawn + Queue/Pipe path, unchanged on the wire: requests
    via ``mp.Queue.put``, responses via a ``Pipe``, death via the
    process sentinel."""

    def __init__(self, wid: int, process, req_q, resp_conn):
        self.id = wid
        self.process = process
        self._req_q = req_q
        self._conn = resp_conn

    def send(self, msg) -> None:
        try:
            self._req_q.put(msg)
        except (OSError, ValueError) as e:
            raise TransportError(f"worker {self.id} request queue closed") \
                from e

    def waitables(self) -> list:
        return [self._conn, self.process.sentinel]

    def pump(self) -> tuple[list, bool]:
        msgs: list = []
        while True:
            try:
                if not self._conn.poll(0):
                    break
                msgs.append(self._conn.recv())
            except (EOFError, OSError, ValueError):
                return msgs, True
            except Exception:  # noqa: BLE001 — partial pickle from a kill
                return msgs, True
        # sentinel fired with the pipe already drained → truly gone; a
        # dead writer's buffered data stays pollable, so the loop above
        # always surfaces results that beat the crash
        return msgs, not self.process.is_alive()

    def kill(self) -> None:
        self.process.kill()

    def close(self) -> None:
        self._req_q.close()
        try:
            self._conn.close()
        except OSError:
            pass

    def join(self, timeout: float | None = None) -> None:
        self.process.join(timeout=timeout)
        if self.process.is_alive():
            self.process.terminate()
            self.process.join(timeout=5)

    def describe(self) -> str:
        return f"local(pid={self.process.pid})"


class LocalTransport(Transport):
    """Spawn-safe worker processes on this host — the default transport
    and the pre-seam behavior, bit for bit."""

    def __init__(self, workers: int = 2, *, mp_context: str = "spawn"):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        self.workers = int(workers)
        self.mp_context = mp_context
        self._cfg: WorkerConfig | None = None

    def _spawn(self, wid: int, cfg: WorkerConfig,
               prefill=None) -> WorkerLink:
        ctx = mp.get_context(self.mp_context)
        req_q = ctx.Queue()
        recv_conn, send_conn = ctx.Pipe(duplex=False)
        proc = ctx.Process(target=_local_worker_main,
                           args=(wid, cfg, req_q, send_conn, None, prefill),
                           name=f"det-front-w{wid}", daemon=True)
        proc.start()
        send_conn.close()  # child owns the send end now
        return LocalLink(wid, proc, req_q, recv_conn)

    def start(self, cfg: WorkerConfig) -> list[WorkerLink]:
        self._cfg = cfg
        return [self._spawn(wid, cfg) for wid in range(self.workers)]

    def redial(self, wid: int) -> WorkerLink | None:
        """Respawn a dead worker's process under the same id."""
        if self._cfg is None:
            return None
        return self._spawn(wid, self._cfg)

    def dial_new(self, wid: int, prefill=None) -> WorkerLink | None:
        """Spawn one more worker process (scale-up is unbounded locally;
        the autoscaler's ``max_workers`` is the policy bound)."""
        if self._cfg is None:
            return None
        return self._spawn(wid, self._cfg, prefill)


# ------------------------------------------------------- shared-memory ring
_SHM_MAGIC = "__shm__"
_SHM_CTRL_BYTES = 16   # two 8-byte-aligned uint64 counters: [head, tail]
_SHM_ALIGN = 64        # payload slots cache-line aligned (and dtype-aligned)


def shm_descriptor(offset, release, shape, dtype) -> tuple:
    """Plain-type wire descriptor for one shm ring payload.

    ``("__shm__", offset, release, shape, dtype_str)`` — ``offset`` is
    the payload's byte position in the ring's data region, ``release``
    the virtual stream position the consumer publishes as the new head
    once the payload is copied out, ``shape``/``dtype`` enough to
    rebuild the ndarray.  Everything is coerced to builtins here so the
    wire never carries numpy scalar types (the reprolint wire-safety
    grammar vets call sites of this builder).
    """
    return (_SHM_MAGIC, int(offset), int(release),
            tuple(int(d) for d in shape), str(dtype))


def is_shm_descriptor(obj) -> bool:
    """True for tuples produced by :func:`shm_descriptor` (the worker's
    decode-time test; inline ndarrays fall through untouched)."""
    return (isinstance(obj, tuple) and len(obj) == 5
            and obj[0] == _SHM_MAGIC)


class ShmRing:
    """Producer side of a per-link single-producer/single-consumer
    shared-memory payload ring (DESIGN_FRONT.md §shm ring protocol).

    Layout: ``head(u64) | tail(u64) | data[capacity]``.  Positions are
    *virtual* (monotonic byte offsets); ``pos % capacity`` locates the
    slot.  Allocations are rounded up to :data:`_SHM_ALIGN` and never
    wrap mid-payload — an allocation that would straddle the end skips
    to the next capacity multiple, so every payload is contiguous and
    dtype-aligned.  The consumer owns ``head`` (its release watermark,
    published after each copy-out in FIFO order — ``mp.Queue`` delivery
    order *is* allocation order, so releases are monotonic); the
    producer owns ``tail``.  A stale ``head`` read under-reports free
    space, which at worst forces the inline-pickle fallback — never
    corruption.

    ``write`` returns ``None`` when the payload doesn't fit (too big
    for the ring, ring full because the worker is behind or dead, ring
    disposed): the caller falls back to sending the ndarray inline, so
    the ring is an overlay fast path, never a liveness dependency.
    """

    # reprolint lock-discipline registry: producer state is touched by
    # the front's drainer thread and close(); the ctrl word stores are
    # single-writer-per-index by protocol.
    _GUARDED_BY = {"_tail": ("_lock",), "_closed": ("_lock",)}

    def __init__(self, capacity: int = 8 << 20):
        from multiprocessing import shared_memory
        if capacity < _SHM_ALIGN:
            raise ValueError(f"ring capacity must be >= {_SHM_ALIGN}")
        self._lock = threading.Lock()
        self.capacity = int(capacity)
        self._shm = shared_memory.SharedMemory(
            create=True, size=_SHM_CTRL_BYTES + self.capacity)
        self._ctrl = np.ndarray((2,), dtype=np.uint64, buffer=self._shm.buf)
        self._ctrl[:] = 0
        self._data = np.ndarray((self.capacity,), dtype=np.uint8,
                                buffer=self._shm.buf, offset=_SHM_CTRL_BYTES)
        self._tail = 0      # virtual write position (mirrors ctrl[1])
        self._closed = False

    @property
    def name(self) -> str:
        return self._shm.name

    def write(self, arr: np.ndarray):
        """Copy ``arr`` into the ring; returns its wire descriptor, or
        ``None`` if it doesn't fit right now (caller sends inline)."""
        arr = np.ascontiguousarray(arr)
        nbytes = int(arr.nbytes)
        alloc = -(-max(nbytes, 1) // _SHM_ALIGN) * _SHM_ALIGN
        if alloc > self.capacity:
            return None
        with self._lock:
            if self._closed:
                return None
            pos = self._tail
            off = pos % self.capacity
            if off + alloc > self.capacity:
                pos += self.capacity - off  # skip the wrap fragment
                off = 0
            # aligned u64 load: the consumer's head only grows, so a
            # torn/stale read can only under-report free space
            head = int(self._ctrl[0])
            if pos + alloc - head > self.capacity:
                return None
            if nbytes:
                self._data[off:off + nbytes] = arr.reshape(-1).view(np.uint8)
            self._tail = pos + alloc
            self._ctrl[1] = np.uint64(self._tail)
            return shm_descriptor(off, self._tail, arr.shape, arr.dtype)

    def dispose(self) -> None:
        """Release the mapping and unlink the segment.  Unlink-early is
        safe on POSIX: the worker's live mapping persists until it
        closes; what's gone is only the name."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            # drop the exporting views before close() (BufferError else)
            self._ctrl = None
            self._data = None
        try:
            self._shm.close()
        except (BufferError, OSError):
            pass
        try:
            self._shm.unlink()
        except (FileNotFoundError, OSError):
            pass


class ShmRingReader:
    """Consumer side: attach by name, resolve descriptors in arrival
    order.  Each :meth:`read` copies the payload out and publishes the
    descriptor's ``release`` watermark as the new head — FIFO decode
    order is the entire reclaim discipline (no per-slot refcounts)."""

    _GUARDED_BY = {"_head": ("_lock",)}

    def __init__(self, name: str):
        from multiprocessing import shared_memory
        self._lock = threading.Lock()
        # attach-side resource_tracker registration is a set-add into
        # the tracker shared with the spawning front (dup of the
        # create-side entry), so the front's dispose() is the one
        # unregister — no bookkeeping needed here
        self._shm = shared_memory.SharedMemory(name=name)
        self._ctrl = np.ndarray((2,), dtype=np.uint64, buffer=self._shm.buf)
        cap = self._shm.size - _SHM_CTRL_BYTES  # size may be page-rounded
        self._data = np.ndarray((cap,), dtype=np.uint8,
                                buffer=self._shm.buf, offset=_SHM_CTRL_BYTES)
        self._head = 0

    def read(self, desc: tuple) -> np.ndarray:
        """Copy the described payload out of the ring and release its
        slot (head := max(head, release))."""
        _, off, release, shape, dtype = desc
        dt = np.dtype(dtype)
        nbytes = dt.itemsize
        for d in shape:
            nbytes *= d
        flat = self._data[off:off + nbytes]
        arr = flat.view(dt).reshape(shape).copy()
        with self._lock:
            if release > self._head:
                self._head = int(release)
                self._ctrl[0] = np.uint64(self._head)
        return arr

    def close(self) -> None:
        self._ctrl = None
        self._data = None
        try:
            self._shm.close()
        except (BufferError, OSError):
            pass


class ShmLink(LocalLink):
    """A :class:`LocalLink` whose batch matrices ride the per-link shm
    ring: control tuples keep their Queue/Pipe framing, each ndarray in
    a ``("batch", …)`` message is replaced by its ring descriptor when
    the ring has room (inline fallback otherwise, per payload).
    Results — scalar dets, or an (m, n) gradient for a grad request —
    ride the response Pipe; only request matrices use the ring."""

    def __init__(self, wid: int, process, req_q, resp_conn, ring: ShmRing):
        super().__init__(wid, process, req_q, resp_conn)
        self.ring = ring

    def send(self, msg) -> None:
        if isinstance(msg, tuple) and msg and msg[0] == "batch":
            pairs = []
            for pr in msg[2]:
                seq, arr = pr[0], pr[1]
                desc = self.ring.write(np.asarray(arr))
                payload = arr if desc is None else desc
                # trailing fields (a grad request's scalar cotangent)
                # stay inline next to the descriptor
                pairs.append((seq, payload) + tuple(pr[2:]))
            msg = ("batch", msg[1], pairs)
        super().send(msg)

    def close(self) -> None:
        super().close()
        self.ring.dispose()

    def describe(self) -> str:
        return f"shm(pid={self.process.pid}, ring={self.ring.name})"


class ShmTransport(LocalTransport):
    """Zero-copy same-host transport: :class:`LocalTransport`'s spawn
    topology and control plane, with a per-link shared-memory ring for
    matrix payloads — no pickle of the matrix bytes, one copy in
    (front) and one copy out (worker) instead of pickle + queue-feeder
    pickle + unpickle.  Bit-identical results by construction: the ring
    carries the exact payload bytes and the worker code path past
    decode is unchanged.  Each redial/dial_new gets a fresh ring, so a
    dead worker's unreleased slots die with its link."""

    def __init__(self, workers: int = 2, *, mp_context: str = "spawn",
                 ring_bytes: int = 8 << 20):
        super().__init__(workers, mp_context=mp_context)
        self.ring_bytes = int(ring_bytes)

    def _spawn(self, wid: int, cfg: WorkerConfig,
               prefill=None) -> WorkerLink:
        ctx = mp.get_context(self.mp_context)
        ring = ShmRing(self.ring_bytes)
        req_q = ctx.Queue()
        recv_conn, send_conn = ctx.Pipe(duplex=False)
        proc = ctx.Process(target=_local_worker_main,
                           args=(wid, cfg, req_q, send_conn, ring.name,
                                 prefill),
                           name=f"det-front-shm-w{wid}", daemon=True)
        proc.start()
        send_conn.close()  # child owns the send end now
        return ShmLink(wid, proc, req_q, recv_conn, ring)


# ------------------------------------------------------------------ sockets
class SocketLink(WorkerLink):
    """One TCP connection to a worker daemon: framed sends under a lock,
    non-blocking framed receives, heartbeat-deadline death detection."""

    # reprolint lock-discipline registry (see DESIGN_LINT.md): the death
    # flag is read by the drainer and written by send failures, pump EOF
    # and kill — all funneled through the send lock.
    _GUARDED_BY = {"_broken": ("_send_lock",)}

    def __init__(self, wid: int, sock, addr: tuple[str, int],
                 hb_timeout: float | None, decoder: FrameDecoder | None = None):
        self.id = wid
        self.addr = addr
        self._sock = sock
        self._send_lock = threading.Lock()
        self._decoder = decoder if decoder is not None else FrameDecoder()
        self._hb_timeout = hb_timeout
        self._last_rx = time.monotonic()
        self._broken = False

    @property
    def broken(self) -> bool:
        with self._send_lock:
            return self._broken

    def _mark_broken(self) -> None:
        with self._send_lock:
            self._broken = True

    def send(self, msg) -> None:
        data = encode_frame(msg)
        try:
            with self._send_lock:
                if self._broken:
                    raise TransportError(f"worker {self.id} link is down")
                self._sock.sendall(data)
        except OSError as e:
            self._mark_broken()
            raise TransportError(
                f"send to worker {self.id} at {self.addr} failed: {e}") \
                from e

    def waitables(self) -> list:
        return [] if self.broken else [self._sock]

    def pump(self) -> tuple[list, bool]:
        if self.broken:
            return [], True
        msgs: list = []
        dead = False
        while True:
            try:
                data = self._sock.recv(1 << 16, socket.MSG_DONTWAIT)
            except (BlockingIOError, InterruptedError):
                break
            except OSError:
                dead = True
                break
            if not data:
                dead = True  # orderly EOF: peer closed
                break
            self._last_rx = time.monotonic()
            try:
                msgs.extend(self._decoder.feed(data))
            except FrameError:
                dead = True  # desync: declare the peer dead, re-route
                break
        out = [m for m in msgs if m[0] != "hb"]  # heartbeats stop here
        if dead:
            self._mark_broken()
        return out, dead

    def expired(self, now: float) -> bool:
        if self.broken:
            return True
        return self._hb_timeout is not None \
            and now - self._last_rx > self._hb_timeout

    def kill(self) -> None:
        # shutdown *before* taking the send lock: a sender stuck in
        # sendall() holds the lock until the shutdown unblocks it, so
        # flag-first (lock, then shutdown) would deadlock the killer
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
        self._mark_broken()

    def close(self) -> None:
        self.kill()

    def describe(self) -> str:
        return f"socket({self.addr[0]}:{self.addr[1]})"


class SocketTransport(Transport):
    """Front over remote worker daemons, one TCP address per worker
    (``det_serve --listen`` on each host).  Worker ids are the address
    indices, so the ring layout — and therefore the re-route order — is
    a pure function of the ``--connect`` list."""

    def __init__(self, addresses, *, spares=(), connect_timeout: float = 30.0,
                 heartbeat_s: float = 1.0, heartbeat_misses: int = 5):
        def norm(a):
            return parse_hostport(a, default_host="127.0.0.1") \
                if isinstance(a, str) else (a[0], int(a[1]))

        addrs = [norm(a) for a in addresses]
        if not addrs:
            raise ValueError("SocketTransport needs at least one address")
        self.addresses = addrs
        # standby daemons the autoscaler may dial on scale-up (FIFO);
        # grown workers get fresh ids past the initial address indices
        self.spare_addresses = [norm(a) for a in spares]
        self._grown_addrs: dict[int, tuple[str, int]] = {}
        self.connect_timeout = float(connect_timeout)
        # a peer silent for this long is declared dead: daemons beat
        # every heartbeat_s, so `misses` whole beats lost in a row means
        # the peer (or the path to it) is gone, not merely busy — the
        # daemon's heartbeat thread is independent of its compute
        self.heartbeat_s = float(heartbeat_s)
        self.hb_timeout = (float(heartbeat_s) * int(heartbeat_misses)
                           if heartbeat_s > 0 else None)

    def _dial(self, addr: tuple[str, int]) -> socket.socket:
        sock = socket.create_connection(addr, timeout=self.connect_timeout)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        return sock

    def _finish(self, sock: socket.socket, wid: int,
                addr: tuple[str, int]):
        """Post-handshake hook: what the link will talk to.  The fault
        battery overrides this to wrap the socket in a frame-mangling
        shim (handshakes stay clean; faults hit only the serving
        stream)."""
        return sock

    def _connect_one(self, wid: int, addr: tuple[str, int],
                     wire_cfg: dict) -> WorkerLink:
        decoder = FrameDecoder()
        try:
            sock = self._dial(addr)
            sock.sendall(encode_frame(("hello", wid, wire_cfg)))
            msg = _read_frame(sock, decoder, timeout=self.connect_timeout,
                              skip_hb=True)
        except (OSError, FrameError) as e:
            raise TransportError(
                f"handshake with worker {wid} at "
                f"{addr[0]}:{addr[1]} failed: {e}") from e
        if msg is None or msg[0] != "ready" or msg[1] != wid:
            raise TransportError(
                f"worker {wid} at {addr[0]}:{addr[1]} answered "
                f"{msg!r}, want ('ready', {wid})")
        sock.settimeout(None)
        # the handshake decoder carries over: bytes that arrived right
        # behind the ready frame must not be lost
        return SocketLink(wid, self._finish(sock, wid, addr), addr,
                          self.hb_timeout, decoder=decoder)

    def start(self, cfg: WorkerConfig) -> list[WorkerLink]:
        wire_cfg = cfg.to_wire()
        wire_cfg["heartbeat_s"] = self.heartbeat_s
        self._wire_cfg = wire_cfg
        links: list[WorkerLink] = []
        try:
            for wid, addr in enumerate(self.addresses):
                links.append(self._connect_one(wid, addr, wire_cfg))
        except TransportError:
            for link in links:
                link.close()
            raise
        return links

    def redial(self, wid: int) -> WorkerLink | None:
        """Re-dial a dead worker's address: a fresh daemon session with
        an empty queue (the daemon re-plans — the same bit-identical
        re-plan a death already forces)."""
        if not hasattr(self, "_wire_cfg"):
            return None
        addr = self._grown_addrs.get(wid)
        if addr is None:
            if wid >= len(self.addresses):
                return None
            addr = self.addresses[wid]
        return self._connect_one(wid, addr, self._wire_cfg)

    def add_spare(self, addr) -> None:
        """Register a standby daemon address for a later ``dial_new``."""
        self.spare_addresses.append(
            parse_hostport(addr, default_host="127.0.0.1")
            if isinstance(addr, str) else (addr[0], int(addr[1])))

    def dial_new(self, wid: int, prefill=None) -> WorkerLink | None:
        """Dial the next standby daemon as a brand-new worker; ``None``
        when no spares remain (the pool is at its physical ceiling).
        ``prefill`` rides the hello's wire dict: the daemon warms those
        plan families before answering ready."""
        if not hasattr(self, "_wire_cfg") or not self.spare_addresses:
            return None
        addr = self.spare_addresses.pop(0)
        wire_cfg = self._wire_cfg
        if prefill:
            wire_cfg = dict(wire_cfg)
            wire_cfg["prefill"] = list(prefill)
        link = self._connect_one(wid, addr, wire_cfg)
        self._grown_addrs[wid] = addr
        return link


def _read_frame(sock: socket.socket, decoder: FrameDecoder,
                timeout: float | None = None, skip_hb: bool = False):
    """Blocking read of one whole frame (handshake path); ``None`` on
    EOF.  Raises ``socket.timeout``/:class:`FrameError` on trouble."""
    sock.settimeout(timeout)
    while True:
        data = sock.recv(1 << 16)
        if not data:
            return None
        msgs = decoder.feed(data)
        if skip_hb:
            msgs = [m for m in msgs if m[0] != "hb"]
        if msgs:
            return msgs[0]


# ----------------------------------------------------------- worker daemon
def run_worker_server(host: str, port: int, *, serve_once: bool = False,
                      max_sessions: int | None = None,
                      log=print, on_listen=None) -> None:
    """A socket worker daemon: one ``DetQueue`` + ``DetEngine`` behind a
    TCP listener (the ``det_serve --listen`` entry point).

    Serves one front connection at a time: the front's ``hello``
    carries the full :class:`WorkerConfig`, so the daemon itself is
    configuration-free — start it, point any number of sequential
    fronts at it.  Each session builds a fresh queue (plan caches are
    per-session; a reconnecting front re-plans, which is the same
    bit-identical re-plan a worker death already forces).  The daemon
    heartbeats every ``heartbeat_s`` (from the hello) on an independent
    thread so a long XLA compile cannot look like a death.
    """
    srv = socket.create_server((host, port))
    bound = srv.getsockname()
    log(f"det-worker listening on {bound[0]}:{bound[1]}", flush=True)
    if on_listen is not None:
        on_listen(bound[0], bound[1])
    limit = 1 if serve_once else max_sessions
    served = 0
    try:
        while True:
            conn, addr = srv.accept()
            try:
                _serve_front_session(conn, addr, log)
            except (OSError, FrameError) as e:
                log(f"det-worker: session from {addr} dropped: {e}",
                    flush=True)
            finally:
                try:
                    conn.close()
                except OSError:
                    pass
            served += 1
            if limit is not None and served >= limit:
                break
    finally:
        srv.close()


def _serve_front_session(conn: socket.socket, addr, log) -> None:
    conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    decoder = FrameDecoder()
    hello = _read_frame(conn, decoder, timeout=60.0)
    if hello is None or hello[0] != "hello":
        raise FrameError(f"expected hello, got {hello!r}")
    _, wid, wire_cfg = hello
    cfg = WorkerConfig.from_wire(wire_cfg)
    heartbeat_s = float(wire_cfg.get("heartbeat_s", 1.0))
    conn.settimeout(None)
    cfg.apply_x64()
    q = cfg.make_queue()
    prefill = wire_cfg.get("prefill")
    if prefill:
        # The front shipped its live plan-family working set: warm the
        # engine now (store first, compile second) — strictly before
        # the ready below, which is what admits this worker to the
        # ring.  A warm-started joiner therefore never serves a request
        # it hasn't planned for (DESIGN_PERSIST.md).
        warmed = q.prefill(prefill)
        log(f"det-worker: prefilled {warmed}/{len(prefill)} plan "
            f"families for front {addr}", flush=True)
    log(f"det-worker: serving front {addr} as worker {wid}", flush=True)

    wlock = threading.Lock()

    def send_raw(msg) -> None:
        data = encode_frame(msg)
        with wlock:
            conn.sendall(data)

    requests: _queue.Queue = _queue.Queue()
    hb_stop = threading.Event()

    def reader() -> None:
        # framed reads → the loop's request queue; EOF/desync from the
        # front is a stop: the queue drains what it accepted (sends to
        # a gone front fail silently) and the daemon goes back to accept
        try:
            while True:
                data = conn.recv(1 << 16)
                if not data:
                    break
                for m in decoder.feed(data):
                    requests.put(m)
        except FrameError:
            # stream desynchronized: nothing further from this front can
            # be trusted — tear the connection down abruptly so the front
            # sees a *death* (and re-routes), not a clean bye
            try:
                conn.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
        except OSError:
            pass
        requests.put(("stop",))

    def heartbeat() -> None:
        while not hb_stop.wait(heartbeat_s):
            try:
                send_raw(("hb", wid))
            except OSError:
                return

    send_raw(("ready", wid))  # strictly before the first heartbeat
    threading.Thread(target=reader, name="det-worker-reader",
                     daemon=True).start()
    if heartbeat_s > 0:
        threading.Thread(target=heartbeat, name="det-worker-hb",
                         daemon=True).start()
    try:
        run_worker_loop(wid, q, requests.get, requests.get_nowait, send_raw)
    finally:
        hb_stop.set()
    log(f"det-worker: front {addr} session ended", flush=True)


def run_worker_client(front_addr: str, *, connect_timeout: float = 30.0,
                      log=print) -> None:
    """Dial into a *running* front's ``--accept`` listener and serve one
    session — live join, direction reversed from ``run_worker_server``
    (the ``det_serve --join host:port`` entry point).

    The wire is identical to the accept path: the front speaks first
    (``("hello", wid, cfg)`` with a freshly assigned worker id and the
    full :class:`WorkerConfig`), the worker answers ``("ready", wid)``
    and runs the same :func:`_serve_front_session` loop — one handshake
    shape regardless of who dialed, so routing and bucketing can never
    disagree with the rest of the pool.  Returns when the front retires
    or stops the worker (or the connection dies).
    """
    host, port = parse_hostport(front_addr, default_host="127.0.0.1")
    conn = socket.create_connection((host, port), timeout=connect_timeout)
    log(f"det-worker joining front at {host}:{port}", flush=True)
    try:
        _serve_front_session(conn, (host, port), log)
    finally:
        try:
            conn.close()
        except OSError:
            pass


class ThreadedWorkerServer:
    """An in-process worker daemon on ``127.0.0.1:<ephemeral>`` — the
    loopback building block for the fault battery: real sockets, real
    frames, real heartbeats, but no subprocess spawn cost and full
    visibility from the test.  Serves ``max_sessions`` front sessions
    (default one; reconnect tests want two)."""

    def __init__(self, start_timeout: float = 30.0, max_sessions: int = 1):
        self._ready = threading.Event()
        self._max_sessions = max_sessions
        self.address: str | None = None
        self._thread = threading.Thread(
            target=self._run, name="det-worker-thread", daemon=True)
        self._thread.start()
        if not self._ready.wait(start_timeout):
            raise TransportError("in-thread worker daemon never listened")

    def _run(self) -> None:
        def on_listen(host: str, port: int) -> None:
            self.address = f"{host}:{port}"
            self._ready.set()

        def quiet(*args, **kwargs) -> None:
            pass

        try:
            run_worker_server("127.0.0.1", 0,
                              max_sessions=self._max_sessions, log=quiet,
                              on_listen=on_listen)
        except Exception:  # noqa: BLE001 — a test teardown race, not news
            pass

    def close(self, timeout: float = 30.0) -> None:
        """Unblock a never-connected accept() so the thread can exit."""
        if self._thread.is_alive() and self.address:
            host, port = parse_hostport(self.address)
            try:
                socket.create_connection((host, port), timeout=2).close()
            except OSError:
                pass
        self._thread.join(timeout=timeout)


def spawn_worker_daemon(host: str = "127.0.0.1", port: int = 0, *,
                        serve_once: bool = True, timeout: float = 60.0):
    """Start ``det_serve --listen`` as a subprocess and wait for its
    "listening" line; returns ``(Popen, "host:port")``.  The loopback
    building block for tests and the benchmark's socket leg."""
    import os
    import pathlib
    import re
    import subprocess
    import sys

    src = str(pathlib.Path(__file__).resolve().parents[2])
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    args = [sys.executable, "-m", "repro.launch.det_serve",
            "--listen", f"{host}:{port}"]
    if serve_once:
        args.append("--serve-once")
    proc = subprocess.Popen(args, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True, env=env)
    deadline = time.monotonic() + timeout
    line = ""
    while time.monotonic() < deadline:
        line = proc.stdout.readline()
        if not line:
            break
        m = re.search(r"det-worker listening on ([\d.]+):(\d+)", line)
        if m:
            return proc, f"{m.group(1)}:{m.group(2)}"
    proc.kill()
    raise TransportError(
        f"worker daemon did not report a listening address: {line!r}")
