import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes, with 512 placeholder host devices.

For each cell it prints ``compiled.memory_analysis()`` (proves it fits)
and ``compiled.cost_analysis()`` (FLOPs/bytes for §Roofline), parses the
optimized HLO for collective operand bytes, and writes one JSON per cell
to ``results/dryrun/`` so the roofline tables and perf iterations read
from artifacts, not reruns.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k --mesh single   # one cell
"""

import argparse
import json
import re
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import get_config, list_archs
from repro.configs.shapes import (SHAPES, abstract_cache, abstract_params,
                                  applicable, input_specs, model_flops,
                                  param_count)
from repro.launch.mesh import make_production_mesh, make_rules
from repro.launch.steps import (make_decode_step, make_prefill_step,
                                make_train_step)
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init
from repro.parallel.sharding import (spec_for, tree_param_shardings,
                                     use_rules)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8,
                "s32": 4, "u64": 8, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\S+)\s+"
                     r"([\w\-]+)\(")
_OPER_RE = re.compile(r"%([\w\.\-]+)")


def _type_bytes(tystr: str) -> int:
    """bytes of an HLO type string like 'bf16[8,128]{1,0}' or tuples."""
    total = 0
    for m in _SHAPE_RE.finditer(tystr):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in optimized HLO.

    HLO prints operands as %name refs; we build a name→result-type map
    first, then per collective line sum its operands' byte sizes.  Also
    records per-op-kind totals and replica-group sizes.
    """
    name_ty: dict[str, str] = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _DEF_RE.match(ln)
        if m:
            name, ty, _ = m.groups()
            name_ty[name] = ty
    out = {k: 0 for k in COLLECTIVES}
    n_ops = 0
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name, ty, op = m.groups()
        kind = next((c for c in COLLECTIVES if op.startswith(c)), None)
        if kind is None:
            continue
        if op.startswith(f"{kind}-start"):
            pass  # count starts; skip matching -done (same buffer)
        elif op.endswith("-done"):
            continue
        n_ops += 1
        args = ln[m.end():].split(")", 1)[0]
        operands = _OPER_RE.findall(args)
        b = sum(_type_bytes(name_ty.get(o, "")) for o in operands)
        if b == 0:  # fallback: result type
            b = _type_bytes(ty)
        out[kind] += b
    out["total_bytes"] = sum(out[k] for k in COLLECTIVES)
    out["n_ops"] = n_ops
    return out


def batch_shardings(specs: dict, rules):
    """NamedShardings for the data inputs (batch dims over pod+data)."""
    mesh = rules.mesh
    out = {}
    for k, v in specs.items():
        logical = ["batch"] + [None] * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, spec_for(v.shape, logical, rules.act,
                                              mesh))
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: dict | None = None):
    """Lower + compile one (arch × shape × mesh) cell.  Returns
    (lowered, compiled, meta)."""
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    ok, reason = applicable(cfg, shape_name)
    if not ok:
        return None, None, {"skipped": reason}
    sh = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(cfg, mesh)
    model = build_model(cfg)
    aparams = abstract_params(cfg)
    psh = tree_param_shardings(aparams, model.logical_axes(), rules)
    specs = input_specs(cfg, shape_name)
    bsh = batch_shardings(specs, rules)
    t0 = time.time()
    with use_rules(rules), mesh:
        if sh.kind == "train":
            opt_cfg = AdamWConfig()
            aopt = jax.eval_shape(partial(adamw_init, cfg=opt_cfg), aparams)
            osh = {"step": NamedSharding(mesh, P()), "mu": psh, "nu": psh}
            step = make_train_step(model, opt_cfg)
            lowered = jax.jit(step, in_shardings=(psh, osh, bsh),
                              donate_argnums=(0, 1)).lower(
                aparams, aopt, specs)
        elif sh.kind == "prefill":
            step = make_prefill_step(model, max_len=sh.seq)
            lowered = jax.jit(step, in_shardings=(psh, bsh)).lower(
                aparams, specs)
        else:  # decode
            acache = abstract_cache(cfg, shape_name)
            cax = model.cache_logical_axes(acache)
            csh = jax.tree.map(
                lambda l, s: NamedSharding(
                    mesh, spec_for(s.shape, l, rules.act, mesh)),
                cax, acache,
                is_leaf=lambda x: isinstance(x, tuple))
            step = make_decode_step(model)
            lowered = jax.jit(step, in_shardings=(psh, csh, bsh),
                              donate_argnums=(1,)).lower(
                aparams, acache, specs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    meta = {"t_lower_s": round(t_lower, 2),
            "t_compile_s": round(t_compile, 2)}
    return lowered, compiled, meta


def _costs(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    coll = parse_collectives(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": float(coll["total_bytes"]),
            "coll_detail": coll}


def _scaled_layers(cfg, L: int):
    """Reduced-depth variant of cfg keeping the layer mix (period) and
    scaling the encoder stack proportionally for enc-dec archs."""
    kw = {"n_layers": L, "scan_layers": False}
    if cfg.enc_dec:
        kw["n_enc_layers"] = max(1, round(cfg.n_enc_layers
                                          * L / cfg.n_layers))
    return kw


def extrapolate_costs(arch: str, shape_name: str, multi_pod: bool,
                      cfg, overrides: dict | None = None) -> dict:
    """XLA's cost_analysis counts a while-loop (scan) body ONCE, so the
    scanned full model undercounts FLOPs by ~n_layers×.  Fix: compile two
    small UNROLLED depths L1 < L2, fit cost(L) = a + b·L, report at full
    depth.  Valid because layer cost is depth-independent (verified by the
    fit's two points) and all inner loops (SSD chunk scan) hold only O(1)
    state updates."""
    period = cfg.local_global_period or 1
    L1 = max(2, period)
    L2 = 2 * L1
    base_ov = dict(overrides or {})
    if L2 >= cfg.n_layers:  # shallow configs: just unroll fully
        _, compiled, _ = lower_cell(arch, shape_name, multi_pod,
                                    dict(base_ov, scan_layers=False))
        c = _costs(compiled)
        return {"flops": c["flops"], "bytes": c["bytes"],
                "coll": c["coll"], "coll_detail": c["coll_detail"],
                "method": "unrolled-full"}
    out = {}
    for L in (L1, L2):
        _, compiled, _ = lower_cell(arch, shape_name, multi_pod,
                                    dict(base_ov, **_scaled_layers(cfg, L)))
        out[L] = _costs(compiled)
        del compiled
    full = {}
    for k in ("flops", "bytes", "coll"):
        b = (out[L2][k] - out[L1][k]) / (L2 - L1)
        a = out[L1][k] - b * L1
        full[k] = a + b * cfg.n_layers
    full["coll_detail"] = {
        kk: (out[L1]["coll_detail"][kk]
             + (out[L2]["coll_detail"][kk] - out[L1]["coll_detail"][kk])
             / (L2 - L1) * (cfg.n_layers - L1))
        for kk in COLLECTIVES}
    full["method"] = f"linear-extrapolation L={L1},{L2}"
    return full


HBM_PER_CHIP = 16 * 2**30  # v5e


def analyze(compiled, cfg, shape_name, mesh_name, n_chips,
            costs: dict | None = None) -> dict:
    ma = compiled.memory_analysis()
    mem = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes"):
        mem[f] = int(getattr(ma, f, 0))
    live = mem["argument_size_in_bytes"] + mem["temp_size_in_bytes"] \
        + mem["output_size_in_bytes"] - mem["alias_size_in_bytes"]
    rec = {
        "arch": cfg.name, "shape": shape_name, "mesh": mesh_name,
        "n_chips": n_chips,
        "memory": mem,
        "live_bytes_per_device": live,
        "fits_hbm_16g": bool(live <= HBM_PER_CHIP),
        "model_flops_global": model_flops(cfg, shape_name),
        "param_count": param_count(cfg),
    }
    if costs is not None:
        rec.update({
            "hlo_flops_per_device": costs["flops"],
            "hlo_bytes_per_device": costs["bytes"],
            "collective_bytes_per_device": costs["coll"],
            "collectives": costs["coll_detail"],
            "cost_method": costs["method"],
        })
    return rec


def run_cell(arch, shape_name, multi_pod, outdir, overrides=None,
             tag="", optimized=False):
    if optimized:
        from repro.configs.registry import OPTIMIZED_OVERRIDES
        overrides = dict(OPTIMIZED_OVERRIDES.get(arch, {}),
                         **(overrides or {}))
        tag = tag + "__opt"
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    cell = f"{arch}__{shape_name}__{mesh_name}{tag}"
    path = os.path.join(outdir, cell + ".json")
    if os.path.exists(path):
        print(f"[skip-cached] {cell}")
        return json.load(open(path))
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    try:
        # 1) full-depth scanned compile: the multi-pod/memory proof
        lowered, compiled, meta = lower_cell(arch, shape_name, multi_pod,
                                             overrides)
        if compiled is None:
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                   "skipped": meta["skipped"]}
            print(f"[skip] {cell}: {meta['skipped']}")
        else:
            # 2) cost extrapolation from small unrolled depths
            #    (roofline table is single-pod; skip the extra compiles
            #     on the multi-pod pass)
            costs = None
            if not multi_pod:
                costs = extrapolate_costs(arch, shape_name, multi_pod, cfg,
                                          overrides)
            n_chips = 512 if multi_pod else 256
            rec = analyze(compiled, cfg, shape_name, mesh_name, n_chips,
                          costs)
            rec.update(meta)
            msg = (f"[ok] {cell}:"
                   f" mem(arg={rec['memory']['argument_size_in_bytes']/2**30:.2f}"
                   f"+tmp={rec['memory']['temp_size_in_bytes']/2**30:.2f} GiB,"
                   f" fits16g={rec['fits_hbm_16g']})"
                   f" compile={meta['t_compile_s']}s")
            if costs:
                msg += (f" flops/dev={rec['hlo_flops_per_device']:.3e}"
                        f" bytes/dev={rec['hlo_bytes_per_device']:.3e}"
                        f" coll/dev={rec['collective_bytes_per_device']:.3e}")
            print(msg)
            print(f"     memory_analysis: {compiled.memory_analysis()}")
            del compiled, lowered
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
        print(f"[FAIL] {cell}: {rec['error']}")
    os.makedirs(outdir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the per-arch §Perf winning knob sets")
    ap.add_argument("--outdir", default=RESULTS)
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, args.outdir,
                               optimized=args.optimized)
                failures += 1 if "error" in rec else 0
    print(f"done; failures={failures}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
