"""Quickstart: the paper's algorithm end to end on one page.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (combinations_lex, combinatorial_addition, comb,
                        radic_det, radic_det_distributed, radic_det_oracle,
                        unrank_py)
from repro.kernels import ops

# 1. Rank-addressable enumeration (paper §4, Example 1) ------------------
print("C(8,5) =", comb(8, 5))
print("B_49 via combinatorial addition:", combinatorial_addition(49, 8, 5))
print("   (paper says [2,5,6,7,8]; dictionary order check:",
      combinations_lex(8, 5)[49], ")")

# 2. Radic determinant of a non-square matrix (Definition 3) -------------
rng = np.random.default_rng(0)
A = rng.normal(size=(4, 9)).astype(np.float32)
print("\nA is 4x9 => sum over C(9,4) =", comb(9, 4), "signed minors")
print("oracle (numpy enumeration):", radic_det_oracle(A))
print("flat jnp (rank-parallel)  :", float(radic_det(jnp.asarray(A))))
print("fused Pallas kernel       :",
      float(ops.radic_det_pallas(jnp.asarray(A), tile=64)))
print("mesh-distributed grains   :",
      float(radic_det_distributed(jnp.asarray(A), grains_per_device=4)))

# 3. The grain scheme scales to bigint rank spaces -----------------------
n, m = 64, 32
print(f"\nC({n},{m}) = {comb(n, m)} (≈1.8e18): grain starts still exact:")
print("  grain 10^17 starts at", unrank_py(10**17, n, m)[:8], "...")
