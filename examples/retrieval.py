"""Video retrieval with non-square determinant signatures — the paper's
motivating application ([8], [20-23]: retrieval over feature matrices of
*different sizes*, which is exactly what Radic's determinant admits).

Each "video" is an m×n_i feature matrix (m pooled channels, n_i frames —
n_i varies per video).  Signature: Radic determinants of sliding (m × w)
windows, a size-invariant descriptor.  A query is a noisy clip of one
video; nearest-signature retrieval must find its source.

Two upgrades over the naive formulation:

* the window determinants are evaluated in **one batched dispatch**
  (:func:`repro.core.radic_det_batched` over the (K, m, w) window
  stack) instead of a Python loop of scalar calls — same numbers, one
  compiled program (the loop is kept below only as a parity check);
* retrieval is sharpened by **gradient-based query refinement**: the
  query signature is differentiable in the query features (the
  ``custom_vjp`` of DESIGN_GRAD.md), so for each shortlisted candidate
  we descend a few steps on the query perturbation that aligns the
  signatures, and re-rank by the aligned distance.  The true source
  needs only a small, cheap perturbation; an impostor needs a large
  one.

  PYTHONPATH=src python examples/retrieval.py
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import radic_det, radic_det_batched

M, W, STRIDE = 4, 6, 2     # pooled channels, window frames, window stride
REFINE_TOPK = 3            # candidates taken into the refinement round
REFINE_STEPS = 25
REFINE_LR = 0.1
RIDGE = 0.05               # perturbation penalty: impostors must pay for it


def window_stack(feats: jnp.ndarray) -> jnp.ndarray:
    """Sliding (M, W) windows of an (M, n) feature matrix -> (K, M, W).
    Shapes are static per n, so this traces/jits cleanly."""
    n = feats.shape[1]
    return jnp.stack([
        jax.lax.dynamic_slice_in_dim(feats, s, W, axis=1)
        for s in range(0, n - W + 1, STRIDE)])


def signature(feats: jnp.ndarray) -> jnp.ndarray:
    """L2-normalized vector of windowed Radic determinants — one batched
    dispatch over the window stack."""
    dets = radic_det_batched(window_stack(feats))
    return dets / (jnp.linalg.norm(dets) + 1e-8)


def signature_loop(feats: np.ndarray) -> np.ndarray:
    """The naive scalar-loop signature (one radic_det call per window),
    kept as the parity reference for the batched path."""
    sig = [float(radic_det(jnp.asarray(feats[:, s:s + W])))
           for s in range(0, feats.shape[1] - W + 1, STRIDE)]
    sig = np.array(sig, np.float32)
    return sig / (np.linalg.norm(sig) + 1e-8)


def sim(a: np.ndarray, b: np.ndarray) -> float:
    L = min(len(a), len(b))
    return float(a[:L] @ b[:L])


@functools.partial(jax.jit, static_argnames=("L",))
def _refine_step(delta, Q, target, L):
    """One descent step on the query perturbation: pull the (truncated)
    query signature toward the candidate's, ridge-penalizing the
    perturbation.  Differentiates through radic_det_batched."""
    def loss(d):
        s = signature(Q + d)
        return jnp.sum((s[:L] - target[:L]) ** 2) + RIDGE * jnp.sum(d * d)
    val, g = jax.value_and_grad(loss)(delta)
    return delta - REFINE_LR * g, val


def refined_distance(Q: jnp.ndarray, target_sig: jnp.ndarray) -> float:
    """How cheaply a small query perturbation aligns the signatures —
    the re-ranking score (lower = better match)."""
    L = min(int(signature(Q).shape[0]), int(target_sig.shape[0]))
    delta = jnp.zeros_like(Q)
    val = jnp.inf
    for _ in range(REFINE_STEPS):
        delta, val = _refine_step(delta, Q, target_sig, L)
    return float(val)


def main():
    rng = np.random.default_rng(0)
    library = [rng.normal(size=(M, rng.integers(18, 40))).astype(np.float32)
               for _ in range(12)]             # different n_i per video!
    sigs = [np.asarray(signature(jnp.asarray(v))) for v in library]

    # batched-vs-loop parity: the one-dispatch signature must reproduce
    # the scalar-loop signature (same flat evaluator, one slot per rank)
    worst = max(float(np.max(np.abs(s - signature_loop(v))))
                for v, s in zip(library, sigs))
    print(f"batched-vs-loop signature parity: worst |diff| = {worst:.2e}")
    assert worst <= 1e-5, worst

    hits = refined_hits = 0
    for target in range(len(library)):
        clip = library[target] + 0.35 * rng.normal(
            size=library[target].shape).astype(np.float32)
        Q = jnp.asarray(clip)
        q = np.asarray(signature(Q))
        ranked = sorted(range(len(library)), key=lambda i: -sim(q, sigs[i]))
        hit = ranked[0] == target
        hits += hit

        # gradient round: re-rank the shortlist by aligned distance
        short = ranked[:REFINE_TOPK]
        dists = {i: refined_distance(Q, jnp.asarray(sigs[i])) for i in short}
        best = min(short, key=dists.get)
        rhit = best == target
        refined_hits += rhit
        print(f"query from video {target:2d} (n={library[target].shape[1]}) "
              f"-> sim {ranked[0]:2d} {'OK  ' if hit else 'MISS'} "
              f"| refined {best:2d} {'OK' if rhit else 'MISS'}")

    print(f"\ntop-1 accuracy: similarity {hits}/{len(library)}, "
          f"gradient-refined {refined_hits}/{len(library)}")
    assert refined_hits >= hits, "refinement must not lose matches"
    assert refined_hits >= 10, "retrieval degraded"


if __name__ == "__main__":
    main()
