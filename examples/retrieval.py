"""Video retrieval with non-square determinant signatures — the paper's
motivating application ([8], [20-23]: retrieval over feature matrices of
*different sizes*, which is exactly what Radic's determinant admits).

Each "video" is an m×n_i feature matrix (m pooled channels, n_i frames —
n_i varies per video).  Signature: Radic determinants of sliding (m × w)
windows, a size-invariant descriptor.  A query is a noisy clip of one
video; nearest-signature retrieval must find its source.

  PYTHONPATH=src python examples/retrieval.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import radic_det

M, W = 4, 6               # pooled channels, window frames


def signature(feats: np.ndarray, stride: int = 2) -> np.ndarray:
    sig = []
    for s in range(0, feats.shape[1] - W + 1, stride):
        sig.append(float(radic_det(jnp.asarray(feats[:, s:s + W]))))
    sig = np.array(sig, np.float32)
    return sig / (np.linalg.norm(sig) + 1e-8)


def sim(a: np.ndarray, b: np.ndarray) -> float:
    L = min(len(a), len(b))
    return float(a[:L] @ b[:L])


rng = np.random.default_rng(0)
library = [rng.normal(size=(M, rng.integers(18, 40))).astype(np.float32)
           for _ in range(12)]                 # different n_i per video!
sigs = [signature(v) for v in library]

hits = 0
for target in range(len(library)):
    clip = library[target] + 0.05 * rng.normal(
        size=library[target].shape).astype(np.float32)
    q = signature(clip)
    ranked = sorted(range(len(library)), key=lambda i: -sim(q, sigs[i]))
    hit = ranked[0] == target
    hits += hit
    print(f"query from video {target:2d} (n={library[target].shape[1]}) "
          f"-> retrieved {ranked[0]:2d} {'OK' if hit else 'MISS'}")
print(f"\ntop-1 accuracy: {hits}/{len(library)}")
assert hits >= 10, "retrieval degraded"
