"""End-to-end training driver example: a small LM from the zoo on the
synthetic pipeline, with checkpoint/restart, via the production launcher.

Defaults are CPU-sized; on real hardware scale with the flags, e.g.
--d-model 768 --layers 12 --vocab 32000 --steps 300 (~100M params).

  PYTHONPATH=src python examples/train_lm.py --steps 40
"""
import argparse

from repro.launch import train as train_driver
from repro.models.config import ModelConfig
import repro.configs.registry as registry

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=40)
ap.add_argument("--d-model", type=int, default=256)
ap.add_argument("--layers", type=int, default=4)
ap.add_argument("--vocab", type=int, default=2048)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
args = ap.parse_args()

cfg = ModelConfig(
    name="example-lm", family="dense",
    n_layers=args.layers, d_model=args.d_model,
    n_heads=args.d_model // 64, n_kv_heads=max(1, args.d_model // 128),
    head_dim=64, d_ff=int(2.75 * args.d_model) // 8 * 8,
    vocab_size=args.vocab, dtype="float32", param_dtype="float32",
    remat=False)

# register so the production train driver can --arch it
registry.ARCHS["example-lm"] = "example_lm_dynamic"
import sys, types
mod = types.ModuleType("repro.configs.example_lm_dynamic")
mod.CONFIG = cfg
mod.smoke = lambda: cfg
sys.modules["repro.configs.example_lm_dynamic"] = mod

losses = train_driver.main([
    "--arch", "example-lm", "--steps", str(args.steps),
    "--batch", str(args.batch), "--seq", str(args.seq),
    "--ckpt", args.ckpt, "--ckpt-every", "20", "--lr", "1e-3"])
assert losses[-1] < losses[0], "loss must decrease"
print("OK: loss went from %.3f to %.3f" % (losses[0], losses[-1]))
