"""End-to-end training driver example: a small LM from the zoo on the
synthetic pipeline, with checkpoint/restart, via the production launcher
— then a determinant-regularized probe head on top (DESIGN_GRAD.md).

Defaults are CPU-sized; on real hardware scale with the flags, e.g.
--d-model 768 --layers 12 --vocab 32000 --steps 300 (~100M params).

  PYTHONPATH=src python examples/train_lm.py --steps 40
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import radic_det
from repro.launch import train as train_driver
from repro.models.config import ModelConfig
import repro.configs.registry as registry

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=40)
ap.add_argument("--d-model", type=int, default=256)
ap.add_argument("--layers", type=int, default=4)
ap.add_argument("--vocab", type=int, default=2048)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
ap.add_argument("--head-steps", type=int, default=150,
                help="probe-head fine-tune steps (det-regularized)")
args = ap.parse_args()

cfg = ModelConfig(
    name="example-lm", family="dense",
    n_layers=args.layers, d_model=args.d_model,
    n_heads=args.d_model // 64, n_kv_heads=max(1, args.d_model // 128),
    head_dim=64, d_ff=int(2.75 * args.d_model) // 8 * 8,
    vocab_size=args.vocab, dtype="float32", param_dtype="float32",
    remat=False)

# register so the production train driver can --arch it
registry.ARCHS["example-lm"] = "example_lm_dynamic"
import sys, types
mod = types.ModuleType("repro.configs.example_lm_dynamic")
mod.CONFIG = cfg
mod.smoke = lambda: cfg
sys.modules["repro.configs.example_lm_dynamic"] = mod

losses = train_driver.main([
    "--arch", "example-lm", "--steps", str(args.steps),
    "--batch", str(args.batch), "--seq", str(args.seq),
    "--ckpt", args.ckpt, "--ckpt-every", "20", "--lr", "1e-3"])
assert losses[-1] < losses[0], "loss must decrease"
print("OK: loss went from %.3f to %.3f" % (losses[0], losses[-1]))


# ---- determinant-regularized probe head --------------------------------
# A non-square (k, d) readout head fit on a *rank-deficient* probe task
# collapses toward low rank — every output reads the same direction.
# Radic's determinant measures exactly that (it is zero iff the head's
# rows are linearly dependent, Definition 3 / Corollary 2), and it is
# now differentiable end to end (the custom_vjp of DESIGN_GRAD.md), so
# `-lam * log |radic_det(H)|` is a drop-in rank regularizer: gradient
# descent trades a sliver of probe loss for a head that keeps its rows
# independent.
K_HEAD, D_HEAD = 3, 8


def fit_head(lam: float, steps: int, seed: int = 0):
    """Fit H (k, d) to a rank-1 probe task; returns (H, final mse,
    target variance)."""
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    X = jax.random.normal(k0, (256, D_HEAD))
    u = jax.random.normal(k1, (D_HEAD,))
    v = jax.random.normal(k2, (K_HEAD,))
    Y = (X @ u)[:, None] * v[None, :]        # rank-1 targets
    H = 0.1 * jax.random.normal(k1, (K_HEAD, D_HEAD))

    @jax.jit
    def step(H):
        def loss(H):
            mse = jnp.mean((X @ H.T - Y) ** 2)
            reg = -lam * jnp.log(jnp.abs(radic_det(H)) + 1e-6)
            return mse + reg, mse
        (_, mse), g = jax.value_and_grad(loss, has_aux=True)(H)
        return H - 0.05 * g, mse

    mse = jnp.inf
    for _ in range(steps):
        H, mse = step(H)
    return H, float(mse), float(jnp.var(Y))


H_plain, mse_plain, var_y = fit_head(0.0, args.head_steps)
H_reg, mse_reg, _ = fit_head(0.02, args.head_steps)
det_plain = abs(float(radic_det(H_plain)))
det_reg = abs(float(radic_det(H_reg)))
print(f"probe head: mse {mse_plain:.4f} -> {mse_reg:.4f} with det reg "
      f"(target var {var_y:.2f}), |radic_det| {det_plain:.2e} -> "
      f"{det_reg:.2e}")
assert det_reg > 10 * det_plain, \
    "det regularizer must keep the head full-rank"
assert mse_reg < 0.05 * var_y, "det reg wrecked the probe fit"
print("OK: determinant-regularized head stays full-rank")
