"""§Perf — Radic core: paper-faithful baseline vs beyond-paper optimized.

Levels (all numerically cross-checked against the enumeration oracle):

  B0  paper-faithful transcription: independent unranking per rank (the
      PRAM-CRCW shape), row-take gather, LAPACK-style LU determinant
      (`jnp.linalg.det`), f32 sum.
  O1  one-hot MXU-matmul gather + lane-batched pivoted GE (the kernel
      math, run as plain jit — measurable on CPU and HLO-countable).
  O2  O1 packaged as the fused Pallas kernel (VMEM-resident pipeline):
      structural metrics (HBM bytes/rank, arithmetic intensity, VMEM
      footprint/tile) + interpret-mode correctness.  Interpret wall-time
      is NOT a TPU predictor and is reported only for completeness.
  O3  grain mode (successor walk) — removes the int32 rank-width limit;
      measured per-rank cost of the walk itself.

Each level reports wall µs/rank (CPU) and HLO FLOPs/rank from
`cost_analysis` of a single chunk (no loops → no while-body undercount).

  PYTHONPATH=src python -m benchmarks.perf_radic
"""

from __future__ import annotations

import timeit

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comb, radic_det_oracle, unrank_jnp
from repro.core.pascal import binom_table
from repro.core.radic import radic_sign
from repro.kernels import ops
from repro.kernels.common import batched_det_ge, onehot_gather_minors

M, N = 6, 24
CHUNK = 4096


def _wall(fn, *args, number=3):
    fn(*args)
    return min(timeit.repeat(lambda: jax.block_until_ready(fn(*args)),
                             number=number, repeat=3)) / number * 1e6


def _flops_per_rank(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    ca = c.cost_analysis() or {}
    return float(ca.get("flops", 0)) / CHUNK, \
        float(ca.get("bytes accessed", 0)) / CHUNK


def level_b0(A, table, qs):
    combos = unrank_jnp(qs, N, M, table)
    minors = jnp.take(A.T, combos - 1, axis=0)
    dets = jnp.linalg.det(minors)
    return jnp.sum(radic_sign(combos, M) * dets)


def level_o1(A, table, qs):
    combos = unrank_jnp(qs, N, M, table)
    minors = onehot_gather_minors(A, combos)
    dets = batched_det_ge(minors)
    return jnp.sum(radic_sign(combos, M).astype(dets.dtype) * dets)


def main():
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(size=(M, N)).astype(np.float32))
    table = jnp.asarray(binom_table(N, M, dtype=np.int32))
    qs = jnp.arange(CHUNK, dtype=jnp.int32)
    want = float(jax.jit(level_b0)(A, table, qs))
    got = float(jax.jit(level_o1)(A, table, qs))
    assert abs(got - want) < 1e-2 * max(1, abs(want)), (got, want)

    print(f"# radic perf: m={M} n={N} C(n,m)={comb(N, M)} chunk={CHUNK}")
    print("level,wall_us_per_rank,hlo_flops_per_rank,"
          "hlo_bytes_per_rank,notes")
    for name, fn in (("B0_paper_faithful", level_b0),
                     ("O1_onehot_ge", level_o1)):
        jf = jax.jit(fn)
        wall = _wall(jf, A, table, qs) / CHUNK
        fl, by = _flops_per_rank(fn, A, table, qs)
        print(f"{name},{wall:.3f},{fl:.0f},{by:.0f},")

    # O2: the fused kernel — structural metrics (TPU target)
    flops_rank = 2 * M * M * N + (2 / 3) * M ** 3 + 4 * M * N
    hbm = (M * N * 4 + (N + 1) * (M + 1) * 4 + 4)
    tile = 256
    vmem = (tile * M * N * 4      # one-hot
            + tile * M * M * 4    # minors
            + tile * (M + 8) * 4  # unrank state + dets
            + M * N * 4 + (N + 1) * (M + 1) * 4)
    print(f"O2_fused_pallas,structural,{flops_rank:.0f},"
          f"{hbm / comb(N, M):.2e},"
          f"AI={flops_rank * comb(N, M) / hbm:.2e}flop/B "
          f"VMEM/tile={vmem / 2 ** 10:.0f}KiB")
    got2 = float(ops.radic_det_pallas(A, count=CHUNK, tile=512))
    assert abs(got2 - want) < 1e-2 * max(1, abs(want))
    print("O2_correctness,interpret-mode,,,matches B0 on "
          f"ranks[0,{CHUNK})")

    # O3: grain successor walk cost
    from repro.core.unrank import successor_jnp
    combos = unrank_jnp(qs, N, M, table)
    js = jax.jit(lambda c: successor_jnp(c, N))
    wall = _wall(js, combos) / CHUNK
    fl, by = _flops_per_rank(lambda c: successor_jnp(c, N), combos)
    print(f"O3_successor_step,{wall:.3f},{fl:.0f},{by:.0f},"
          "grain mode: no int32 limit")

    # numerics: kahan vs plain at scale (vs float64 oracle)
    from repro.core import radic_det
    want64 = radic_det_oracle(np.asarray(A))
    plain = float(radic_det(A, chunk=CHUNK))
    kahan = float(radic_det(A, chunk=CHUNK, kahan=True))
    print(f"numerics,err_plain={abs(plain - want64):.2e},"
          f"err_kahan={abs(kahan - want64):.2e},,"
          f"C(n,m)={comb(N, M)} signed terms")


if __name__ == "__main__":
    main()
