"""§Perf — synchronous drain vs async pipelined determinant serving.

The synchronous ``drain_queue`` reference serializes stage (pad + stack
+ upload), dispatch and complete per batch; the ``DetQueue`` pipeline
overlaps them on a two-thread pipeline and re-buckets dynamically (merging
under-filled shape buckets via det-exact zero column padding, splitting
hot ones).  Wall-clock for a mixed-shape queue is therefore bounded by
the *slowest* pipeline stage instead of their sum.  Both sides are
jit-warm (compile time excluded) and numerics are cross-checked.

``--arrival poisson`` replaces the all-at-once burst with an open-loop
Poisson arrival process (exponential inter-arrival gaps at ``--rate``
requests/s) against a backlog-bounded queue (``--max-pending``): the
report adds latency percentiles, the load-shed count and the backlog
peak — the admission-control tuning loop for ``linger_s``/``max_pending``
that DESIGN_ENGINE.md describes.

``--workers N`` switches to the multi-worker front sweep: the same
multi-shape Poisson workload is pushed through the single-process
``DetQueue`` and through ``DetFront`` pools of 1..N workers, against
the synchronous single-queue drain as the throughput baseline — the
report is one row per serving tier (throughput + sojourn percentiles),
and full runs assert the ``FRONT_SPEEDUP_FLOOR`` on the N-worker row.

  PYTHONPATH=src python -m benchmarks.perf_serve            # full run
  PYTHONPATH=src python -m benchmarks.perf_serve --smoke    # CI-sized
  PYTHONPATH=src python -m benchmarks.perf_serve \\
      --arrival poisson --rate 400 --max-pending 64
  PYTHONPATH=src python -m benchmarks.perf_serve --workers 2
"""

from __future__ import annotations

import argparse
import json
import math
import os
import time

import numpy as np

from repro.launch.det_queue import BucketPolicy, DetQueue, LoadShedError
from repro.launch.det_serve import _random_queue, drain_queue

# full-run acceptance floor: overlapped serving must beat the synchronous
# drain by this factor on a mixed queue of >= 256 matrices (CPU)
SPEEDUP_FLOOR = 1.3

# full-run acceptance floor for the multi-worker front (--workers 2):
# pool throughput on a multi-shape Poisson workload must beat the
# synchronous single-queue drain by this factor (CPU)
FRONT_SPEEDUP_FLOOR = 1.5

# full-run acceptance floor for the shm transport: same-host per-batch
# front overhead (large degenerate payloads => worker compute ~ zero)
# must drop by this factor vs the Queue/Pipe pickle path
SHM_OVERHEAD_FLOOR = 2.0

# full-run acceptance floor for the combo-reuse batched kernel: at
# serving batch depth (B >= 8) it must beat the legacy (B, tiles) grid
COMBO_KERNEL_FLOOR = 1.3


def _wall(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _submit_poisson(server, mats, arrivals):
    """Open-loop submission at scheduled arrival times against anything
    with the queue surface (``DetQueue`` or ``DetFront``).  The arrival
    process never slows down when the server falls behind.  Arrivals
    that fall due together (``time.sleep`` granularity, ~ms) are
    submitted as one ``submit_many`` burst — the client analogue of the
    stager's snapshot: scheduling fidelity below a millisecond is OS
    noise, and per-request submission would serialize the *client* and
    measure its pickling loop instead of the server.  Returns
    ``(wall_s, sorted sojourn latencies of served requests, shed)``."""
    done_t: dict[int, float] = {}

    def stamp(f):
        done_t[f.seq] = time.perf_counter()

    subs = []
    t0 = time.perf_counter()
    i = 0
    while i < len(mats):
        now = time.perf_counter() - t0
        if arrivals[i] > now:
            time.sleep(arrivals[i] - now)
            now = time.perf_counter() - t0
        j = i
        while j < len(mats) and arrivals[j] <= now:
            j += 1
        t_sub = time.perf_counter()
        for fut in server.submit_many(mats[i:j]):
            fut.add_done_callback(stamp)
            subs.append((fut, t_sub))
        i = j
    shed = 0
    for fut, _ in subs:
        try:
            fut.result(timeout=600)
        except LoadShedError:
            shed += 1
    wall = time.perf_counter() - t0
    # result() can return before the done-callback stamp has run (the
    # resolver invokes callbacks after waking waiters), so wait for the
    # stragglers before reading done_t — they land within microseconds
    deadline = time.monotonic() + 5.0
    while len(done_t) < len(subs) and time.monotonic() < deadline:
        time.sleep(0.001)
    lat = np.sort([done_t[f.seq] - t_sub for f, t_sub in subs
                   if f.seq in done_t and f.exception() is None])
    return wall, lat, shed


def _pct_ms(lat, p: float) -> float:
    if not len(lat):
        return float("nan")
    return float(lat[min(len(lat) - 1, int(p * len(lat)))]) * 1e3


def measure(num: int = 256, max_m: int = 5, max_n: int = 16, *,
            chunk: int = 2048, backend: str = "jnp", max_batch: int = 32,
            seed: int = 0, policy: str = "auto", repeat: int = 3) -> dict:
    """Timed sync-vs-async comparison on one mixed-shape queue.

    The two sides are timed in alternating sync/async pairs (best-of-
    ``repeat`` each) so machine-load drift lands on both equally instead
    of skewing whichever side ran later.
    """
    mats = _random_queue(num, max_m, max_n, seed)

    def sync():
        return drain_queue(mats, chunk=chunk, backend=backend,
                           max_batch=max_batch)[0]

    q = DetQueue(chunk=chunk, backend=backend,
                 policy=BucketPolicy(max_batch=max_batch, mode=policy))
    try:
        sync_dets = sync()  # warm: compiles every (shape, capacity) program
        async_dets, _ = q.serve(mats)  # warm
        q.reset_stats()  # count the timed repeats only, not warm+compile
        t_sync = t_async = float("inf")
        for _ in range(repeat):
            t_sync = min(t_sync, _wall(sync))
            t_async = min(t_async, _wall(lambda: q.serve(mats)))
        stats = q.snapshot()
    finally:
        q.close()

    # numerics: merge padding is det-exact, so both paths agree tightly
    np.testing.assert_allclose(np.asarray(async_dets),
                               np.asarray(sync_dets), rtol=1e-4, atol=1e-5)
    return {
        "num": num, "policy": policy,
        "sync_s": t_sync, "async_s": t_async,
        "sync_mats_per_s": num / t_sync,
        "async_mats_per_s": num / t_async,
        "speedup": t_sync / t_async,
        # stats were reset after warm: totals cover `repeat` serves
        "batches": stats["batches"] // repeat,
        "merged_requests": stats["merged_requests"] // repeat,
    }


def measure_poisson(num: int = 256, rate: float = 400.0, *, max_m: int = 5,
                    max_n: int = 16, chunk: int = 2048,
                    backend: str = "jnp", max_batch: int = 32,
                    seed: int = 0, policy: str = "auto",
                    max_pending: int | None = 64,
                    linger_s: float = 0.0) -> dict:
    """Open-loop Poisson arrivals against a backlog-bounded DetQueue.

    Each request is submitted at its scheduled arrival time (exponential
    gaps, mean ``1/rate``) regardless of completion progress — the
    arrival process does not slow down when the server falls behind,
    which is exactly what exposes the backlog bound: overflowing
    submissions are shed (:class:`LoadShedError`) instead of growing the
    queue and the tail latency without limit.  Reports achieved
    throughput, shed/backlog counters and sojourn-time percentiles
    (submit → future resolution) over the served requests.
    """
    mats = _random_queue(num, max_m, max_n, seed)
    gaps = np.random.default_rng(seed + 1).exponential(1.0 / rate, size=num)
    arrivals = np.cumsum(gaps)
    q = DetQueue(chunk=chunk, backend=backend,
                 policy=BucketPolicy(max_batch=max_batch, mode=policy),
                 max_pending=max_pending, linger_s=linger_s)
    try:
        # warm in backlog-sized waves so compile time is excluded without
        # tripping admission control
        step = max_pending if max_pending is not None else num
        for base in range(0, num, step):
            q.serve(mats[base:base + step])
        q.reset_stats()
        wall, lat, _ = _submit_poisson(q, mats, arrivals)
        q.poll(timeout=0)
        stats = q.snapshot()
    finally:
        q.close()

    served, shed = stats["completed"], stats["shed"]
    assert served + shed == num, (served, shed, num)

    return {
        "num": num, "policy": policy, "rate_offered": rate,
        "rate_achieved": num / wall, "served": served, "shed": shed,
        "shed_frac": shed / num, "served_per_s": served / wall,
        "backlog_peak": stats["backlog_peak"],
        "batches": stats["batches"],
        "latency_p50_ms": _pct_ms(lat, 0.50),
        "latency_p95_ms": _pct_ms(lat, 0.95),
        "latency_p99_ms": _pct_ms(lat, 0.99),
    }


def head_shapes(max_m: int = 7, target_ranks: int = 15000,
                per_m: int = 3) -> list[tuple[int, int]]:
    """An *equal-work* hot-shape set: for each row count m, the first
    ``per_m`` column widths whose rank space C(n, m) lands within
    [0.7x, 1.6x] of ``target_ranks``.

    Production request streams concentrate on a head of recurring
    shapes — a head-shape workload is what separates the serving
    architecture effects (batching, overlap, horizontal scale) from the
    long-tail compile churn the LRU plan caches exist for.  Keeping the
    per-shape work comparable matters for the *pool* measurement: the
    consistent-hash ring splits shapes, so wildly uneven shape weights
    would measure placement luck, not scaling.
    """
    lo, hi = int(target_ranks * 0.7), int(target_ranks * 1.6)
    shapes = []
    for m in range(3, max_m + 1):
        found = 0
        for n in range(m, 80):
            c = math.comb(n, m)
            if c > hi:
                break
            if c >= lo:
                shapes.append((m, n))
                found += 1
                if found >= per_m:
                    break
    return shapes


def _head_shape_queue(num: int, seed: int):
    shapes = head_shapes()
    rng = np.random.default_rng(seed)
    return [rng.normal(
        size=shapes[int(rng.integers(0, len(shapes)))]).astype(np.float32)
        for _ in range(num)]


def measure_front(num: int = 512, workers: int = 2, *, rate: float = 20000.0,
                  chunk: int = 2048,
                  backend: str = "jnp", max_batch: int = 32, seed: int = 0,
                  policy: str = "never", repeat: int = 3,
                  socket_loopback: bool = False) -> list[dict]:
    """Front-vs-single-queue sweep on one multi-shape Poisson workload.

    Every serving tier gets the *same* head-shape request set (see
    :func:`head_shapes`) and the same Poisson arrival schedule.  The
    offered rate defaults far above CPU service capacity on purpose:
    throughput is then service-bound, which is the thing the front's
    horizontal scaling moves (an offered rate below capacity measures
    the arrival process, not the server).  Rows: the synchronous
    single-queue drain (throughput baseline), the in-process
    ``DetQueue``, and ``DetFront`` pools up to ``workers`` processes —
    each with throughput and sojourn-time percentiles.
    """
    from repro.launch.det_front import DetFront

    mats = _head_shape_queue(num, seed)
    arrivals = np.cumsum(
        np.random.default_rng(seed + 1).exponential(1.0 / rate, size=num))
    # exact-shape buckets + pinned capacity: open-loop trickles produce
    # arbitrary batch depths, and every unseen (shape, capacity) pair
    # would be a fresh XLA compile mid-measurement — pinning makes the
    # program set exactly one per head shape, fully covered by the warm
    # pass (the deterministic serving configuration the bit-identity
    # tests also pin).  The pin bound is a padding-waste bound, not a
    # throughput knob: a pinned batch pays its full capacity in device
    # work whether or not it filled, and the last slice of every
    # per-shape group is partial, so a small pin keeps the worst-case
    # waste near ceil(k/8)/(k/8) ~ 1.1 while the linger window below
    # lets batches actually fill under the offered rate (the
    # fill-vs-latency trade DESIGN_SERVE.md describes; it shows up in
    # the sojourn p50).
    pol = BucketPolicy(max_batch=min(max_batch, 16), mode=policy,
                       pin_capacity=True)
    # batching window: stage only once the snapshot is deep enough to
    # fill the hot buckets' pinned batches (or the window expires) —
    # without the depth gate a trickle stages thin per-bucket groups
    # that each pay a full pinned batch of padded device work
    n_shapes = len(head_shapes())
    linger_s, stage_depth = 0.010, pol.max_batch * n_shapes
    rows: list[dict] = []

    def sync():
        return drain_queue(mats, chunk=chunk, backend=backend,
                           max_batch=max_batch)[0]

    sync()  # warm: compiles every (shape, capacity) program in-process
    t_sync = min(_wall(sync) for _ in range(repeat))
    rows.append({"tier": "drain_sync", "workers": 0, "wall_s": t_sync,
                 "mats_per_s": num / t_sync, "p50_ms": float("nan"),
                 "p95_ms": float("nan"), "p99_ms": float("nan"),
                 "speedup_vs_drain": 1.0})

    def poisson_tier(name: str, server, nworkers: int):
        try:
            futs = server.submit_many(mats)  # warm: full-batch programs
            for f in futs:
                f.result(timeout=600)
            server.poll(timeout=0)
            _submit_poisson(server, mats, arrivals)  # warm: trickle-depth
            server.poll(timeout=0)                   # capacity programs
            server.reset_stats()
            wall, lat = float("inf"), []
            for _ in range(repeat):
                w, l, _ = _submit_poisson(server, mats, arrivals)
                server.poll(timeout=0)
                if w < wall:
                    wall, lat = w, l
        finally:
            server.close()
        rows.append({"tier": name, "workers": nworkers, "wall_s": wall,
                     "mats_per_s": num / wall,
                     "p50_ms": _pct_ms(lat, 0.50),
                     "p95_ms": _pct_ms(lat, 0.95),
                     "p99_ms": _pct_ms(lat, 0.99),
                     "speedup_vs_drain": t_sync / wall})

    poisson_tier("queue", DetQueue(chunk=chunk, backend=backend,
                                   policy=pol, linger_s=linger_s,
                                   stage_depth=stage_depth), 1)
    for k in sorted({1, workers}):
        poisson_tier(f"front_w{k}",
                     DetFront(workers=k, chunk=chunk, backend=backend,
                              policy=pol, linger_s=linger_s,
                              pin_workers=True,
                              stage_depth=max(pol.max_batch,
                                              stage_depth // k)), k)
    # the --shm leg: the same pool size over the zero-copy shm ring —
    # what dropping the Queue/Pipe pickle path saves on the same
    # workload (modest here: these heads are small, so front machinery
    # rather than payload bytes dominates; measure_shm_overhead prices
    # the payload path in isolation)
    poisson_tier(f"front_shm_w{workers}",
                 DetFront(workers=workers, chunk=chunk, backend=backend,
                          policy=pol, linger_s=linger_s, pin_workers=True,
                          shm=True,
                          stage_depth=max(pol.max_batch,
                                          stage_depth // workers)),
                 workers)
    if socket_loopback:
        # the --connect leg: the same pool size over SocketTransport to
        # real daemon subprocesses on loopback — what the wire (framing,
        # acks, heartbeats) costs relative to Queue/Pipe on one host
        from repro.launch.transport import (SocketTransport,
                                            spawn_worker_daemon)
        procs = []
        try:
            addrs = []
            for _ in range(workers):
                proc, addr = spawn_worker_daemon()
                procs.append(proc)
                addrs.append(addr)
            poisson_tier(
                f"front_sock_w{workers}",
                DetFront(transport=SocketTransport(addrs), chunk=chunk,
                         backend=backend, policy=pol, linger_s=linger_s,
                         stage_depth=max(pol.max_batch,
                                         stage_depth // workers)),
                workers)
        finally:
            for proc in procs:
                proc.kill()
                proc.wait(timeout=30)
    return rows


def measure_shm_overhead(num: int = 24, shape: tuple[int, int] = (2048, 1024),
                         *, repeat: int = 3, seed: int = 0) -> dict:
    """Same-host per-batch front overhead: Queue/Pipe pickle vs shm ring.

    Payloads are large *degenerate* ``m > n`` matrices: ``det == 0``
    with an empty rank space, so worker compute is ~nothing and wall
    clock is the transport + front machinery — exactly the overhead the
    shm ring removes (pickle + queue-feeder copy + unpickle become one
    copy in, one copy out).  One worker, so no routing spread; results
    on this path are bit-identical by the transport-fault battery.

    Two measurement traps this deliberately sidesteps:

    - Payloads are *random*, not zeros: an ``np.zeros`` matrix maps
      every page to the kernel zero page, so the pickle side reads one
      cache-resident page instead of paying real memory traffic — the
      baseline looks arbitrarily (and noisily) fast.  The default 8 MB
      payload also exceeds LLC on small hosts, so each of the pickle
      path's extra copies is honest DRAM traffic; cache-resident 2 MB
      payloads under-report the cut ~3x.
    - Submission is a *windowed* pipeline, not one submit_many: a
      single submit_many is one link message carrying every payload at
      once, which on the shm side would fill the ring before the
      worker can release anything and silently degrade most payloads
      to the inline pickle fallback — measuring the fallback, not the
      ring.  A 4-deep window bounds ring residency (~32 MB here, half
      the 64 MB ring) while keeping submit/complete overlapped.
    """
    from repro.launch.det_front import DetFront

    rng = np.random.default_rng(seed)
    mats = [rng.standard_normal(shape).astype(np.float32)
            for _ in range(num)]
    pol = BucketPolicy(max_batch=8, mode="merge", pin_capacity=True)
    walls: dict[str, float] = {}
    for name, shm in (("local", False), ("shm", True)):
        with DetFront(workers=1, policy=pol, shm=shm,
                      shm_ring_bytes=64 << 20) as front:

            def run(ms):
                futs: list = []
                for A in ms:
                    futs.append(front.submit(A))
                    if len(futs) >= 4:
                        futs.pop(0).result(timeout=600)
                for f in futs:
                    f.result(timeout=600)

            run(mats[:8])  # warm the plan path
            front.poll(timeout=0)
            wall = float("inf")
            for _ in range(repeat):
                w = _wall(lambda: run(mats))
                wall = min(wall, w)
                front.poll(timeout=0)
        walls[name] = wall
    return {
        "num": num, "shape": shape,
        "payload_mb": np.prod(shape) * 4 / 2**20,
        "local_us_per_mat": walls["local"] * 1e6 / num,
        "shm_us_per_mat": walls["shm"] * 1e6 / num,
        "speedup": walls["local"] / walls["shm"],
    }


def measure_combo_kernel(batch: int = 8, shape: tuple[int, int] = (4, 12),
                         *, tile: int = 256, repeat: int = 5) -> dict:
    """Combo-reuse batched kernel vs the legacy ``(B, tiles)`` grid.

    Both wrappers sit behind the same ops-level guards and are bit-
    identical (``tests/test_kernel_parity.py``); this prices the reuse:
    unranking/selectors/signs paid once per rank tile instead of B
    times.  Timed in alternating pairs so machine-load drift lands on
    both sides equally.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.default_rng(2)
    m, n = shape
    As = jnp.asarray(rng.normal(size=(batch, m, n)).astype(np.float32))

    def combo():
        jax.block_until_ready(ops.radic_det_batched_pallas(As, tile=tile))

    def bygrid():
        jax.block_until_ready(
            ops.radic_det_batched_pallas_bygrid(As, tile=tile))

    combo()   # compile
    bygrid()  # compile
    t_c = t_g = float("inf")
    for _ in range(repeat):
        t_g = min(t_g, _wall(bygrid))
        t_c = min(t_c, _wall(combo))
    return {
        "batch": batch, "shape": shape, "tile": tile,
        "bygrid_us": t_g * 1e6, "combo_us": t_c * 1e6,
        "bygrid_us_per_mat": t_g * 1e6 / batch,
        "combo_us_per_mat": t_c * 1e6 / batch,
        "speedup": t_g / t_c,
    }


def measure_autoscale(num: int = 256, max_workers: int = 2, *,
                      rate: float = 20000.0, chunk: int = 2048,
                      backend: str = "jnp", max_batch: int = 32,
                      seed: int = 0, policy: str = "never") -> list[dict]:
    """Static 1-worker pool vs an elastic pool under the same Poisson
    workload (the ``launch/autoscale.py`` controller leg).

    Both tiers start as a 1-worker ``DetFront`` on the head-shape
    Poisson workload of :func:`measure_front`; the elastic tier runs the
    SLO autoscaler (fast cadence — bench runs are seconds long), which
    should grow the pool toward ``max_workers`` while the backlog
    breaches and drain it back to one worker once the queue empties.
    Each row reports throughput, sojourn percentiles, shed count and the
    membership trajectory (``scaled_up``/``scaled_down``/final size) —
    the gate the CI smoke asserts is *behavioral*: the pool visibly
    scaled 1→N and back, and elasticity never shed a request the static
    pool would have served.
    """
    from repro.launch.autoscale import Autoscaler
    from repro.launch.det_front import DetFront

    mats = _head_shape_queue(num, seed)
    arrivals = np.cumsum(
        np.random.default_rng(seed + 1).exponential(1.0 / rate, size=num))
    pol = BucketPolicy(max_batch=min(max_batch, 16), mode=policy,
                       pin_capacity=True)
    linger_s = 0.010
    stage_depth = pol.max_batch * len(head_shapes())
    rows: list[dict] = []

    def run_tier(name: str, elastic: bool):
        front = DetFront(workers=1, chunk=chunk, backend=backend,
                         policy=pol, linger_s=linger_s,
                         stage_depth=stage_depth)
        scaler = None
        try:
            futs = front.submit_many(mats)  # warm: compile the head set
            for f in futs:
                f.result(timeout=600)
            front.poll(timeout=0)
            front.reset_stats()
            if elastic:
                scaler = Autoscaler(front, min_workers=1,
                                    max_workers=max_workers,
                                    interval_s=0.05, up_ticks=2,
                                    idle_ticks=4, cooldown_s=0.5,
                                    backlog_high=4.0).start()
            wall, lat, shed = _submit_poisson(front, mats, arrivals)
            front.poll(timeout=0)
            if elastic:
                # drained: give the controller its idle window to shrink
                deadline = time.monotonic() + 30.0
                while (len(front.alive_workers) > 1
                       and time.monotonic() < deadline):
                    time.sleep(0.05)
            snap = front.snapshot()
        finally:
            if scaler is not None:
                scaler.stop()
            front.close()
        rows.append({
            "tier": name, "max_workers": max_workers if elastic else 1,
            "wall_s": wall, "mats_per_s": num / wall, "shed": shed,
            "p50_ms": _pct_ms(lat, 0.50), "p95_ms": _pct_ms(lat, 0.95),
            "p99_ms": _pct_ms(lat, 0.99),
            "scaled_up": scaler.scaled_up if scaler else 0,
            "scaled_down": scaler.scaled_down if scaler else 0,
            "final_workers": snap["front"]["workers_alive"],
            "joined": snap["front"]["joined"],
        })

    run_tier("static_w1", elastic=False)
    run_tier(f"elastic_w1to{max_workers}", elastic=True)
    return rows


# one matrix per family is enough traffic to create every routing bucket:
# capacity is pinned, so the plan (and the prefill entry) for a family is
# the same whether the bucket held 1 matrix or ``cap``
_POPULATE_STORE = """
import sys
from repro.launch.det_queue import BucketPolicy, DetQueue
store, chunk, backend, cap = (sys.argv[1], int(sys.argv[2]), sys.argv[3],
                              int(sys.argv[4]))
fams = [tuple(map(int, f.split("x"))) for f in sys.argv[5].split(",")]
pol = BucketPolicy(max_batch=cap, mode="merge", pin_capacity=True)
q = DetQueue(chunk=chunk, backend=backend, policy=pol, persist_dir=store)
try:
    n = q.prefill([(m, nn, cap) for m, nn in fams])
finally:
    q.close()  # flushes the write-behind store queue
assert n == len(fams), (n, fams)
"""


def measure_join_warmstart(families=((3, 12), (4, 10), (5, 9), (6, 8)), *,
                           chunk: int = 2048, backend: str = "jnp",
                           cap: int = 8, seed: int = 0) -> dict:
    """Cold vs store-warm join latency (the DESIGN_PERSIST.md price row).

    Both tiers run the identical sequence: a 1-worker ``DetFront`` with
    an accept listener serves one matrix per plan family (so the
    placer's owner_map — the prefill list — holds the full family set),
    then a real ``det_serve --join`` worker *subprocess* dials in and
    the clock runs from process spawn to admission.  The joiner is a
    subprocess on purpose: an in-thread joiner would inherit the bench
    process's jit caches and measure those, not the store.

    The only difference between tiers is the store.  Cold:
    ``prefill=True`` with no store, so the joiner compiles every family
    before ``ready``.  Warm: ``persist_dir`` over a store populated by
    an earlier subprocess, so the joiner's prefill restores metadata
    (``store_hits``) and skips each family's XLA compile via the
    compilation cache the store houses.  Both joins pay the same
    interpreter+jax startup and the same tracing — the delta is the
    compile work warm-start removes.
    """
    import shutil
    import subprocess
    import sys
    import tempfile

    from repro.launch.det_front import DetFront

    rng = np.random.default_rng(seed)
    mats = [rng.normal(size=(m, n)).astype(np.float32)
            for (m, n) in families]
    pol = BucketPolicy(max_batch=cap, mode="merge", pin_capacity=True)
    store = tempfile.mkdtemp(prefix="planstore_bench_")
    out: dict = {"families": len(families), "cap": cap, "chunk": chunk}
    try:
        fam_arg = ",".join(f"{m}x{n}" for (m, n) in families)
        subprocess.run(
            [sys.executable, "-c", _POPULATE_STORE, store, str(chunk),
             backend, str(cap), fam_arg],
            check=True, timeout=600)

        def run_tier(warm: bool) -> tuple[float, dict]:
            front = DetFront(workers=1, chunk=chunk, backend=backend,
                             policy=pol, accept="127.0.0.1:0",
                             persist_dir=(store if warm else None),
                             prefill=True)
            proc = None
            try:
                for f in front.submit_many(mats):
                    f.result(timeout=600)
                front.poll(timeout=0)
                before = set(front.alive_workers)
                t0 = time.perf_counter()
                proc = subprocess.Popen(
                    [sys.executable, "-m", "repro.launch.det_serve",
                     "--join", front.accept_address],
                    stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
                deadline = time.monotonic() + 600.0
                while len(front.alive_workers) <= len(before):
                    if time.monotonic() > deadline:
                        raise TimeoutError("joiner never admitted")
                    time.sleep(0.005)
                t_join = time.perf_counter() - t0
                wid = (set(front.alive_workers) - before).pop()
                # the joiner streams its stats with heartbeats; give the
                # first report a moment to land before reading it
                pc: dict = {}
                deadline = time.monotonic() + 10.0
                while time.monotonic() < deadline:
                    wsnap = front.snapshot()["workers"].get(wid) or {}
                    pc = wsnap.get("plan_cache") or {}
                    if pc.get("size", 0) >= len(families):
                        break
                    time.sleep(0.05)
                return t_join, pc
            finally:
                if proc is not None:
                    proc.terminate()
                    proc.wait(timeout=30)
                front.close()

        cold_s, cold_pc = run_tier(warm=False)
        warm_s, warm_pc = run_tier(warm=True)
    finally:
        shutil.rmtree(store, ignore_errors=True)
    out.update({
        "cold_join_s": cold_s, "warm_join_s": warm_s,
        "speedup": cold_s / warm_s,
        "cold_store_hits": int(cold_pc.get("store_hits", 0)),
        "warm_store_hits": int(warm_pc.get("store_hits", 0)),
        "joiner_plans": int(warm_pc.get("size", 0)),
    })
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--num", type=int, default=256)
    ap.add_argument("--max-m", type=int, default=5)
    ap.add_argument("--max-n", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=2048)
    ap.add_argument("--backend", choices=("jnp", "pallas"), default="jnp")
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeat", type=int, default=7)
    ap.add_argument("--attempts", type=int, default=4,
                    help="re-measure attempts before failing the speedup "
                         "floor (wall-clock noise on small shared boxes)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run; skips the speedup-floor assert")
    ap.add_argument("--arrival", choices=("burst", "poisson"),
                    default="burst",
                    help="burst: submit-all-then-drain sync-vs-async "
                         "comparison; poisson: open-loop arrival process "
                         "with admission control")
    ap.add_argument("--rate", type=float, default=400.0,
                    help="poisson arrival rate, requests/s")
    ap.add_argument("--max-pending", type=int, default=64,
                    help="poisson: admission-control backlog bound "
                         "(0 = unbounded)")
    ap.add_argument("--linger", type=float, default=0.0,
                    help="poisson: stager batching window in seconds "
                         "(linger_s) — the trade between batch fill and "
                         "added latency under trickle arrivals")
    ap.add_argument("--workers", type=int, default=0,
                    help="multi-worker front sweep: compare DetFront "
                         "pools up to N workers against the in-process "
                         "queue and the sync drain (0 = off)")
    ap.add_argument("--autoscale", type=int, default=0,
                    help="elastic leg: static 1-worker pool vs a pool the "
                         "SLO autoscaler grows to N and drains back under "
                         "the same Poisson workload (0 = off; gates on "
                         "the membership trajectory, not a speedup floor)")
    ap.add_argument("--socket", action="store_true",
                    help="front sweep: add a SocketTransport loopback "
                         "tier (worker daemons as subprocesses behind "
                         "--listen, front over --connect framing)")
    ap.add_argument("--policy", choices=("auto", "merge", "never"),
                    default="merge",
                    help="front sweep: re-bucketing mode for the queue "
                         "and front tiers (capacity is always pinned — "
                         "one program per canonical bucket)")
    ap.add_argument("--front-rate", type=float, default=20000.0,
                    help="front sweep: offered Poisson rate, requests/s "
                         "(default saturates the CPU service rate so "
                         "throughput is service-bound)")
    ap.add_argument("--json", type=str, default="",
                    help="also dump the result rows as JSON to this path "
                         "(CI uploads it as the per-commit bench artifact)")
    args = ap.parse_args(argv)

    def finish(results):
        if args.json:
            import sys
            payload = {"bench": "perf_serve",
                       "argv": sys.argv[1:] if argv is None else argv,
                       "mode": ("autoscale" if args.autoscale
                                else "front" if args.workers
                                else args.arrival),
                       "workers": args.workers, "smoke": args.smoke,
                       "results": results}
            with open(args.json, "w") as fh:
                json.dump(payload, fh, indent=2, default=str)
            print(f"# json written to {args.json}")
        return results

    if args.autoscale > 0:
        num = 48 if args.smoke else max(args.num, 256)
        rows = measure_autoscale(
            num, args.autoscale, rate=args.front_rate, chunk=args.chunk,
            backend=args.backend, max_batch=args.max_batch, seed=args.seed,
            policy=args.policy)
        print("tier,max_workers,num,wall_s,mats_per_s,shed,p50_ms,p95_ms,"
              "p99_ms,scaled_up,scaled_down,final_workers,joined")
        for r in rows:
            print(f"{r['tier']},{r['max_workers']},{num},{r['wall_s']:.4f},"
                  f"{r['mats_per_s']:.1f},{r['shed']},{r['p50_ms']:.2f},"
                  f"{r['p95_ms']:.2f},{r['p99_ms']:.2f},{r['scaled_up']},"
                  f"{r['scaled_down']},{r['final_workers']},{r['joined']}")
        static, elastic = rows
        # behavioral gate (asserted in smoke too): the pool visibly grew
        # and drained back, and elasticity never shed a request the
        # static pool served
        assert elastic["scaled_up"] >= 1, "autoscaler never scaled up"
        assert elastic["scaled_down"] >= 1, "autoscaler never drained"
        assert elastic["final_workers"] == 1, (
            f"pool ended at {elastic['final_workers']} workers, not 1")
        assert elastic["shed"] <= static["shed"], (
            f"elastic shed {elastic['shed']} > static {static['shed']}")
        return finish(rows)

    if args.workers > 0:
        num = 48 if args.smoke else max(args.num, 384)
        repeat = 1 if args.smoke else 3
        attempts = 1 if args.smoke else max(1, args.attempts)
        print("attempt,tier,workers,num,wall_s,mats_per_s,p50_ms,p95_ms,"
              "p99_ms,speedup_vs_drain")
        # demonstrating W-way parallel scaling needs at least W worker
        # cores plus one for the routing front; on smaller hosts the
        # pool's workers time-slice the same cores the single queue had
        # to itself, so the honest full-run invariant there is "the pool
        # is never slower than the single queue", not the scaling floor
        cores = os.cpu_count() or 1
        scaling_host = cores > args.workers
        best, best_queue = 0.0, 0.0
        rows = []
        for attempt in range(attempts):
            rows = measure_front(
                num, args.workers, rate=args.front_rate, chunk=args.chunk,
                backend=args.backend, max_batch=args.max_batch,
                seed=args.seed, policy=args.policy, repeat=repeat,
                socket_loopback=args.socket)
            for r in rows:
                print(f"{attempt},{r['tier']},{r['workers']},{num},"
                      f"{r['wall_s']:.4f},{r['mats_per_s']:.1f},"
                      f"{r['p50_ms']:.2f},{r['p95_ms']:.2f},"
                      f"{r['p99_ms']:.2f},{r['speedup_vs_drain']:.2f}")
            # the floor is a *scaling* claim: judge it on the full
            # N-worker pool only (front_w1 reaching it via pipeline
            # overlap alone would vacuously pass a 2-worker gate)
            best = max(best, max(r["speedup_vs_drain"] for r in rows
                                 if r["tier"] == f"front_w{args.workers}"))
            best_queue = max(best_queue,
                             max(r["speedup_vs_drain"] for r in rows
                                 if r["tier"] == "queue"))
            if best >= (FRONT_SPEEDUP_FLOOR if scaling_host
                        else best_queue):
                break  # floor demonstrated; later attempts add nothing
        print(f"best_front_speedup,{best:.2f}")
        if not args.smoke:
            if scaling_host:
                assert best >= FRONT_SPEEDUP_FLOOR, (
                    f"front serving {best:.2f}x < {FRONT_SPEEDUP_FLOOR}x "
                    f"floor over the sync drain after {attempts} attempts")
            else:
                print(f"# note: {cores} cores cannot demonstrate "
                      f"{args.workers}-worker scaling; asserting "
                      "pool >= single queue instead")
                assert best >= best_queue, (
                    f"front pool {best:.2f}x slower than the single "
                    f"queue {best_queue:.2f}x after {attempts} attempts")
        # single-host hot-path floors, priced in isolation: the shm ring
        # vs the Queue/Pipe pickle path on payload-bound traffic, and
        # the combo-reuse batched kernel vs the legacy (B, tiles) grid
        # at serving batch depth.  Same pooled-minima attempts logic as
        # above: load noise is one-sided.
        shm_best = combo_best = 0.0
        shm_row: dict = {}
        combo_row: dict = {}
        for attempt in range(attempts):
            sr = measure_shm_overhead(num=8 if args.smoke else 24,
                                      repeat=1 if args.smoke else 3)
            if sr["speedup"] > shm_best:
                shm_best, shm_row = sr["speedup"], sr
            kr = measure_combo_kernel(repeat=2 if args.smoke else 7)
            if kr["speedup"] > combo_best:
                combo_best, combo_row = kr["speedup"], kr
            if (shm_best >= SHM_OVERHEAD_FLOOR
                    and combo_best >= COMBO_KERNEL_FLOOR):
                break
        print("hotpath,metric,baseline_us,fast_us,speedup")
        print(f"hotpath,shm_front_overhead_us_per_mat,"
              f"{shm_row['local_us_per_mat']:.0f},"
              f"{shm_row['shm_us_per_mat']:.0f},{shm_best:.2f}")
        print(f"hotpath,combo_kernel_us_per_batch,"
              f"{combo_row['bygrid_us']:.0f},"
              f"{combo_row['combo_us']:.0f},{combo_best:.2f}")
        if not args.smoke:
            assert shm_best >= SHM_OVERHEAD_FLOOR, (
                f"shm front overhead cut only {shm_best:.2f}x < "
                f"{SHM_OVERHEAD_FLOOR}x floor vs the Queue/Pipe pickle "
                f"path after {attempts} attempts")
            assert combo_best >= COMBO_KERNEL_FLOOR, (
                f"combo-reuse kernel {combo_best:.2f}x < "
                f"{COMBO_KERNEL_FLOOR}x floor vs the legacy grid at "
                f"B={combo_row.get('batch')} after {attempts} attempts")
        rows.append({"tier": "shm_overhead", **shm_row})
        rows.append({"tier": "combo_kernel", **combo_row})
        return finish(rows)

    if args.arrival == "poisson":
        num = 48 if args.smoke else max(args.num, 256)
        max_pending = args.max_pending if args.max_pending > 0 else None
        print("policy,num,rate_offered,rate_achieved,served,shed,shed_frac,"
              "served_per_s,backlog_peak,batches,p50_ms,p95_ms,p99_ms")
        results = {}
        for policy in ("never", "auto"):
            r = measure_poisson(
                num, args.rate, max_m=args.max_m, max_n=args.max_n,
                chunk=args.chunk, backend=args.backend,
                max_batch=args.max_batch, seed=args.seed, policy=policy,
                max_pending=max_pending, linger_s=args.linger)
            results[policy] = r
            print(f"{policy},{r['num']},{r['rate_offered']:.0f},"
                  f"{r['rate_achieved']:.1f},{r['served']},{r['shed']},"
                  f"{r['shed_frac']:.3f},{r['served_per_s']:.1f},"
                  f"{r['backlog_peak']},{r['batches']},"
                  f"{r['latency_p50_ms']:.2f},{r['latency_p95_ms']:.2f},"
                  f"{r['latency_p99_ms']:.2f}")
        return finish(results)

    num = 64 if args.smoke else max(args.num, 256)
    repeat = 1 if args.smoke else args.repeat
    attempts = 1 if args.smoke else max(1, args.attempts)
    print("attempt,policy,num,sync_s,async_s,sync_mats_per_s,"
          "async_mats_per_s,speedup,batches,merged_requests")
    results = {}
    # Machine-load noise is one-sided (it only slows things down), so the
    # floor is judged on pooled minima: the best sync wall across every
    # attempt (the sync workload is identical in all rows) against the
    # best async wall per policy.  Per-row `speedup` stays the honest
    # same-window pairing.
    sync_best = float("inf")
    async_best: dict[str, float] = {}
    best = 0.0
    for attempt in range(attempts):
        for policy in ("never", "auto"):
            r = measure(num, args.max_m, args.max_n, chunk=args.chunk,
                        backend=args.backend, max_batch=args.max_batch,
                        seed=args.seed, policy=policy, repeat=repeat)
            results[policy] = r
            sync_best = min(sync_best, r["sync_s"])
            async_best[policy] = min(async_best.get(policy, float("inf")),
                                     r["async_s"])
            print(f"{attempt},{policy},{r['num']},{r['sync_s']:.4f},"
                  f"{r['async_s']:.4f},{r['sync_mats_per_s']:.1f},"
                  f"{r['async_mats_per_s']:.1f},{r['speedup']:.2f},"
                  f"{r['batches']},{r['merged_requests']}")
        best = max(sync_best / t for t in async_best.values())
        if best >= SPEEDUP_FLOOR:
            break  # floor demonstrated; later attempts add nothing
    print(f"best_speedup,{best:.2f}")
    if not args.smoke:
        assert best >= SPEEDUP_FLOOR, (
            f"overlapped serving {best:.2f}x < {SPEEDUP_FLOOR}x floor "
            f"after {attempts} attempts")
    return finish(results)


if __name__ == "__main__":
    main()
