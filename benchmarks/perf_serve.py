"""§Perf — synchronous drain vs async pipelined determinant serving.

The synchronous ``drain_queue`` reference serializes stage (pad + stack
+ upload), dispatch and complete per batch; the ``DetQueue`` pipeline
overlaps them on a two-thread pipeline and re-buckets dynamically (merging
under-filled shape buckets via det-exact zero column padding, splitting
hot ones).  Wall-clock for a mixed-shape queue is therefore bounded by
the *slowest* pipeline stage instead of their sum.  Both sides are
jit-warm (compile time excluded) and numerics are cross-checked.

``--arrival poisson`` replaces the all-at-once burst with an open-loop
Poisson arrival process (exponential inter-arrival gaps at ``--rate``
requests/s) against a backlog-bounded queue (``--max-pending``): the
report adds latency percentiles, the load-shed count and the backlog
peak — the admission-control tuning loop for ``linger_s``/``max_pending``
that DESIGN_ENGINE.md describes.

  PYTHONPATH=src python -m benchmarks.perf_serve            # full run
  PYTHONPATH=src python -m benchmarks.perf_serve --smoke    # CI-sized
  PYTHONPATH=src python -m benchmarks.perf_serve \\
      --arrival poisson --rate 400 --max-pending 64
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.launch.det_queue import BucketPolicy, DetQueue, LoadShedError
from repro.launch.det_serve import _random_queue, drain_queue

# full-run acceptance floor: overlapped serving must beat the synchronous
# drain by this factor on a mixed queue of >= 256 matrices (CPU)
SPEEDUP_FLOOR = 1.3


def _wall(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def measure(num: int = 256, max_m: int = 5, max_n: int = 16, *,
            chunk: int = 2048, backend: str = "jnp", max_batch: int = 32,
            seed: int = 0, policy: str = "auto", repeat: int = 3) -> dict:
    """Timed sync-vs-async comparison on one mixed-shape queue.

    The two sides are timed in alternating sync/async pairs (best-of-
    ``repeat`` each) so machine-load drift lands on both equally instead
    of skewing whichever side ran later.
    """
    mats = _random_queue(num, max_m, max_n, seed)

    def sync():
        return drain_queue(mats, chunk=chunk, backend=backend,
                           max_batch=max_batch)[0]

    q = DetQueue(chunk=chunk, backend=backend,
                 policy=BucketPolicy(max_batch=max_batch, mode=policy))
    try:
        sync_dets = sync()  # warm: compiles every (shape, capacity) program
        async_dets, _ = q.serve(mats)  # warm
        q.reset_stats()  # count the timed repeats only, not warm+compile
        t_sync = t_async = float("inf")
        for _ in range(repeat):
            t_sync = min(t_sync, _wall(sync))
            t_async = min(t_async, _wall(lambda: q.serve(mats)))
        stats = q.snapshot()
    finally:
        q.close()

    # numerics: merge padding is det-exact, so both paths agree tightly
    np.testing.assert_allclose(np.asarray(async_dets),
                               np.asarray(sync_dets), rtol=1e-4, atol=1e-5)
    return {
        "num": num, "policy": policy,
        "sync_s": t_sync, "async_s": t_async,
        "sync_mats_per_s": num / t_sync,
        "async_mats_per_s": num / t_async,
        "speedup": t_sync / t_async,
        # stats were reset after warm: totals cover `repeat` serves
        "batches": stats["batches"] // repeat,
        "merged_requests": stats["merged_requests"] // repeat,
    }


def measure_poisson(num: int = 256, rate: float = 400.0, *, max_m: int = 5,
                    max_n: int = 16, chunk: int = 2048,
                    backend: str = "jnp", max_batch: int = 32,
                    seed: int = 0, policy: str = "auto",
                    max_pending: int | None = 64,
                    linger_s: float = 0.0) -> dict:
    """Open-loop Poisson arrivals against a backlog-bounded DetQueue.

    Each request is submitted at its scheduled arrival time (exponential
    gaps, mean ``1/rate``) regardless of completion progress — the
    arrival process does not slow down when the server falls behind,
    which is exactly what exposes the backlog bound: overflowing
    submissions are shed (:class:`LoadShedError`) instead of growing the
    queue and the tail latency without limit.  Reports achieved
    throughput, shed/backlog counters and sojourn-time percentiles
    (submit → future resolution) over the served requests.
    """
    mats = _random_queue(num, max_m, max_n, seed)
    gaps = np.random.default_rng(seed + 1).exponential(1.0 / rate, size=num)
    arrivals = np.cumsum(gaps)
    q = DetQueue(chunk=chunk, backend=backend,
                 policy=BucketPolicy(max_batch=max_batch, mode=policy),
                 max_pending=max_pending, linger_s=linger_s)
    try:
        # warm in backlog-sized waves so compile time is excluded without
        # tripping admission control
        step = max_pending if max_pending is not None else num
        for base in range(0, num, step):
            q.serve(mats[base:base + step])
        q.reset_stats()

        done_t: dict[int, float] = {}

        def stamp(f):
            done_t[f.seq] = time.perf_counter()

        submitted = []
        t0 = time.perf_counter()
        for A, t_arr in zip(mats, arrivals):
            lag = t_arr - (time.perf_counter() - t0)
            if lag > 0:
                time.sleep(lag)
            fut = q.submit(A)
            fut.add_done_callback(stamp)
            submitted.append((fut, time.perf_counter()))
        for fut, _ in submitted:
            try:
                fut.result(timeout=300)
            except LoadShedError:
                pass
        wall = time.perf_counter() - t0
        q.poll(timeout=0)
        stats = q.snapshot()
    finally:
        q.close()

    lat = np.sort([done_t[f.seq] - t_sub for f, t_sub in submitted
                   if f.exception() is None])
    served, shed = stats["completed"], stats["shed"]
    assert served + shed == num, (served, shed, num)

    def pct(p):
        return float(lat[min(len(lat) - 1, int(p * len(lat)))]) if len(lat) \
            else float("nan")

    return {
        "num": num, "policy": policy, "rate_offered": rate,
        "rate_achieved": num / wall, "served": served, "shed": shed,
        "shed_frac": shed / num, "served_per_s": served / wall,
        "backlog_peak": stats["backlog_peak"],
        "batches": stats["batches"],
        "latency_p50_ms": pct(0.50) * 1e3, "latency_p95_ms": pct(0.95) * 1e3,
        "latency_p99_ms": pct(0.99) * 1e3,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--num", type=int, default=256)
    ap.add_argument("--max-m", type=int, default=5)
    ap.add_argument("--max-n", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=2048)
    ap.add_argument("--backend", choices=("jnp", "pallas"), default="jnp")
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeat", type=int, default=7)
    ap.add_argument("--attempts", type=int, default=4,
                    help="re-measure attempts before failing the speedup "
                         "floor (wall-clock noise on small shared boxes)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run; skips the speedup-floor assert")
    ap.add_argument("--arrival", choices=("burst", "poisson"),
                    default="burst",
                    help="burst: submit-all-then-drain sync-vs-async "
                         "comparison; poisson: open-loop arrival process "
                         "with admission control")
    ap.add_argument("--rate", type=float, default=400.0,
                    help="poisson arrival rate, requests/s")
    ap.add_argument("--max-pending", type=int, default=64,
                    help="poisson: admission-control backlog bound "
                         "(0 = unbounded)")
    ap.add_argument("--linger", type=float, default=0.0,
                    help="poisson: stager batching window in seconds "
                         "(linger_s) — the trade between batch fill and "
                         "added latency under trickle arrivals")
    args = ap.parse_args(argv)

    if args.arrival == "poisson":
        num = 48 if args.smoke else max(args.num, 256)
        max_pending = args.max_pending if args.max_pending > 0 else None
        print("policy,num,rate_offered,rate_achieved,served,shed,shed_frac,"
              "served_per_s,backlog_peak,batches,p50_ms,p95_ms,p99_ms")
        results = {}
        for policy in ("never", "auto"):
            r = measure_poisson(
                num, args.rate, max_m=args.max_m, max_n=args.max_n,
                chunk=args.chunk, backend=args.backend,
                max_batch=args.max_batch, seed=args.seed, policy=policy,
                max_pending=max_pending, linger_s=args.linger)
            results[policy] = r
            print(f"{policy},{r['num']},{r['rate_offered']:.0f},"
                  f"{r['rate_achieved']:.1f},{r['served']},{r['shed']},"
                  f"{r['shed_frac']:.3f},{r['served_per_s']:.1f},"
                  f"{r['backlog_peak']},{r['batches']},"
                  f"{r['latency_p50_ms']:.2f},{r['latency_p95_ms']:.2f},"
                  f"{r['latency_p99_ms']:.2f}")
        return results

    num = 64 if args.smoke else max(args.num, 256)
    repeat = 1 if args.smoke else args.repeat
    attempts = 1 if args.smoke else max(1, args.attempts)
    print("attempt,policy,num,sync_s,async_s,sync_mats_per_s,"
          "async_mats_per_s,speedup,batches,merged_requests")
    results = {}
    # Machine-load noise is one-sided (it only slows things down), so the
    # floor is judged on pooled minima: the best sync wall across every
    # attempt (the sync workload is identical in all rows) against the
    # best async wall per policy.  Per-row `speedup` stays the honest
    # same-window pairing.
    sync_best = float("inf")
    async_best: dict[str, float] = {}
    best = 0.0
    for attempt in range(attempts):
        for policy in ("never", "auto"):
            r = measure(num, args.max_m, args.max_n, chunk=args.chunk,
                        backend=args.backend, max_batch=args.max_batch,
                        seed=args.seed, policy=policy, repeat=repeat)
            results[policy] = r
            sync_best = min(sync_best, r["sync_s"])
            async_best[policy] = min(async_best.get(policy, float("inf")),
                                     r["async_s"])
            print(f"{attempt},{policy},{r['num']},{r['sync_s']:.4f},"
                  f"{r['async_s']:.4f},{r['sync_mats_per_s']:.1f},"
                  f"{r['async_mats_per_s']:.1f},{r['speedup']:.2f},"
                  f"{r['batches']},{r['merged_requests']}")
        best = max(sync_best / t for t in async_best.values())
        if best >= SPEEDUP_FLOOR:
            break  # floor demonstrated; later attempts add nothing
    print(f"best_speedup,{best:.2f}")
    if not args.smoke:
        assert best >= SPEEDUP_FLOOR, (
            f"overlapped serving {best:.2f}x < {SPEEDUP_FLOOR}x floor "
            f"after {attempts} attempts")
    return results


if __name__ == "__main__":
    main()
