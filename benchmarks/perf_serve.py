"""§Perf — synchronous drain vs async pipelined determinant serving.

The synchronous ``drain_queue`` reference serializes stage (pad + stack
+ upload), dispatch and complete per batch; the ``DetQueue`` pipeline
overlaps them on a two-thread pipeline and re-buckets dynamically (merging
under-filled shape buckets via det-exact zero column padding, splitting
hot ones).  Wall-clock for a mixed-shape queue is therefore bounded by
the *slowest* pipeline stage instead of their sum.  Both sides are
jit-warm (compile time excluded) and numerics are cross-checked.

  PYTHONPATH=src python -m benchmarks.perf_serve            # full run
  PYTHONPATH=src python -m benchmarks.perf_serve --smoke    # CI-sized
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.launch.det_queue import BucketPolicy, DetQueue
from repro.launch.det_serve import _random_queue, drain_queue

# full-run acceptance floor: overlapped serving must beat the synchronous
# drain by this factor on a mixed queue of >= 256 matrices (CPU)
SPEEDUP_FLOOR = 1.3


def _wall(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def measure(num: int = 256, max_m: int = 5, max_n: int = 16, *,
            chunk: int = 2048, backend: str = "jnp", max_batch: int = 32,
            seed: int = 0, policy: str = "auto", repeat: int = 3) -> dict:
    """Timed sync-vs-async comparison on one mixed-shape queue.

    The two sides are timed in alternating sync/async pairs (best-of-
    ``repeat`` each) so machine-load drift lands on both equally instead
    of skewing whichever side ran later.
    """
    mats = _random_queue(num, max_m, max_n, seed)

    def sync():
        return drain_queue(mats, chunk=chunk, backend=backend,
                           max_batch=max_batch)[0]

    q = DetQueue(chunk=chunk, backend=backend,
                 policy=BucketPolicy(max_batch=max_batch, mode=policy))
    try:
        sync_dets = sync()  # warm: compiles every (shape, capacity) program
        async_dets, _ = q.serve(mats)  # warm
        q.reset_stats()  # count the timed repeats only, not warm+compile
        t_sync = t_async = float("inf")
        for _ in range(repeat):
            t_sync = min(t_sync, _wall(sync))
            t_async = min(t_async, _wall(lambda: q.serve(mats)))
        stats = q.snapshot()
    finally:
        q.close()

    # numerics: merge padding is det-exact, so both paths agree tightly
    np.testing.assert_allclose(np.asarray(async_dets),
                               np.asarray(sync_dets), rtol=1e-4, atol=1e-5)
    return {
        "num": num, "policy": policy,
        "sync_s": t_sync, "async_s": t_async,
        "sync_mats_per_s": num / t_sync,
        "async_mats_per_s": num / t_async,
        "speedup": t_sync / t_async,
        # stats were reset after warm: totals cover `repeat` serves
        "batches": stats["batches"] // repeat,
        "merged_requests": stats["merged_requests"] // repeat,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--num", type=int, default=256)
    ap.add_argument("--max-m", type=int, default=5)
    ap.add_argument("--max-n", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=2048)
    ap.add_argument("--backend", choices=("jnp", "pallas"), default="jnp")
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeat", type=int, default=7)
    ap.add_argument("--attempts", type=int, default=4,
                    help="re-measure attempts before failing the speedup "
                         "floor (wall-clock noise on small shared boxes)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run; skips the speedup-floor assert")
    args = ap.parse_args(argv)

    num = 64 if args.smoke else max(args.num, 256)
    repeat = 1 if args.smoke else args.repeat
    attempts = 1 if args.smoke else max(1, args.attempts)
    print("attempt,policy,num,sync_s,async_s,sync_mats_per_s,"
          "async_mats_per_s,speedup,batches,merged_requests")
    results = {}
    # Machine-load noise is one-sided (it only slows things down), so the
    # floor is judged on pooled minima: the best sync wall across every
    # attempt (the sync workload is identical in all rows) against the
    # best async wall per policy.  Per-row `speedup` stays the honest
    # same-window pairing.
    sync_best = float("inf")
    async_best: dict[str, float] = {}
    best = 0.0
    for attempt in range(attempts):
        for policy in ("never", "auto"):
            r = measure(num, args.max_m, args.max_n, chunk=args.chunk,
                        backend=args.backend, max_batch=args.max_batch,
                        seed=args.seed, policy=policy, repeat=repeat)
            results[policy] = r
            sync_best = min(sync_best, r["sync_s"])
            async_best[policy] = min(async_best.get(policy, float("inf")),
                                     r["async_s"])
            print(f"{attempt},{policy},{r['num']},{r['sync_s']:.4f},"
                  f"{r['async_s']:.4f},{r['sync_mats_per_s']:.1f},"
                  f"{r['async_mats_per_s']:.1f},{r['speedup']:.2f},"
                  f"{r['batches']},{r['merged_requests']}")
        best = max(sync_best / t for t in async_best.values())
        if best >= SPEEDUP_FLOOR:
            break  # floor demonstrated; later attempts add nothing
    print(f"best_speedup,{best:.2f}")
    if not args.smoke:
        assert best >= SPEEDUP_FLOOR, (
            f"overlapped serving {best:.2f}x < {SPEEDUP_FLOOR}x floor "
            f"after {attempts} attempts")
    return results


if __name__ == "__main__":
    main()
