"""Benchmark harness — one function per paper claim (the paper is an
algorithm paper; its "tables" are the complexity claims of §4–§6).

Prints ``name,us_per_call,derived`` CSV rows:

  unrank_*        §4: combinatorial addition cost per rank (the O(m(n-m))
                  claim) — host / vectorized jnp / Pallas kernel
  minor_det_*     the [7]-replacement: batched m×m determinant throughput
  radic_*         end-to-end Radic determinant vs the sequential
                  enumeration baseline (the paper's comparison point)
  grains_*        §5: granularity scheme — grain balance + successor cost
  fused_ai        derived arithmetic intensity of the fused kernel (the
                  roofline argument for the TPU mapping)
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import statistics
import time
import timeit
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (comb, plan_grains, radic_det, radic_det_distributed,
                        radic_det_oracle, unrank_jnp, unrank_py)
from repro.kernels import ops

ROWS: list[tuple[str, float, str]] = []


def row(name: str, us: float, derived: str = ""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.3f},{derived}")


def _timeit(fn, number=5, repeat=3) -> float:
    fn()  # compile/warm
    t = min(timeit.repeat(fn, number=number, repeat=repeat)) / number
    return t * 1e6


# ---------------------------------------------------------------- unranking
def bench_unrank(n=24, m=12, batch=4096):
    total = comb(n, m)
    qs = np.linspace(0, total - 1, batch, dtype=np.int64)
    t_host = _timeit(lambda: [unrank_py(int(q), n, m)
                              for q in qs[:64]], number=1)
    row("unrank_host_python", t_host / 64, f"n={n} m={m} per-rank")
    qs32 = jnp.asarray(qs.astype(np.int32))
    f = jax.jit(lambda q: unrank_jnp(q, n, m)).lower(qs32).compile()
    t = _timeit(lambda: jax.block_until_ready(f(qs32)))
    row("unrank_jnp_vectorized", t / batch, f"batch={batch} per-rank")
    t = _timeit(lambda: jax.block_until_ready(
        ops.unrank(qs32, n, m, tile=512)), number=2)
    row("unrank_pallas_interpret", t / batch,
        "per-rank (interpret mode; TPU target)")


# --------------------------------------------------------------- minor dets
def bench_minor_det(batch=2048, m=8):
    rng = np.random.default_rng(0)
    mats = jnp.asarray(rng.normal(size=(batch, m, m)).astype(np.float32))
    t_np = _timeit(lambda: np.linalg.det(np.asarray(mats)), number=3)
    row("minor_det_numpy_lapack", t_np / batch, f"m={m} per-det")
    f = jax.jit(jnp.linalg.det).lower(mats).compile()
    t = _timeit(lambda: jax.block_until_ready(f(mats)))
    row("minor_det_jnp_lu", t / batch, f"m={m} per-det")
    t = _timeit(lambda: jax.block_until_ready(
        ops.minor_det(mats, tile=256)), number=2)
    row("minor_det_pallas_interpret", t / batch,
        f"m={m} per-det (interpret)")


# ----------------------------------------------------------------- end2end
def bench_radic(m=5, n=22):
    total = comb(n, m)
    rng = np.random.default_rng(1)
    A = rng.normal(size=(m, n)).astype(np.float32)
    Aj = jnp.asarray(A)
    t0 = time.perf_counter()
    want = radic_det_oracle(A)
    t_seq = (time.perf_counter() - t0) * 1e6
    row("radic_sequential_oracle", t_seq,
        f"m={m} n={n} C={total} (paper's baseline)")
    f = jax.jit(lambda a: radic_det(a, chunk=4096)).lower(Aj).compile()
    got = float(f(Aj))
    assert abs(got - want) < 1e-2 * max(1, abs(want)), (got, want)
    t = _timeit(lambda: jax.block_until_ready(f(Aj)), number=2)
    row("radic_flat_jnp", t, f"speedup_vs_seq={t_seq / t:.1f}x "
        f"us_per_rank={t / total:.4f}")
    t = _timeit(lambda: jax.block_until_ready(
        ops.radic_det_pallas(Aj, tile=1024)), number=1, repeat=2)
    row("radic_fused_pallas_interpret", t,
        f"us_per_rank={t / total:.4f} (interpret; TPU target)")
    t = _timeit(lambda: jax.block_until_ready(
        radic_det_distributed(Aj, grains_per_device=4)), number=1,
        repeat=2)
    row("radic_grains_successor", t, f"us_per_rank={t / total:.4f}")


# -------------------------------------------------------------- grains (§5)
def bench_grains(n=40, m=20, k=4096):
    total = comb(n, m)  # ~138 billion ranks: bigint-only territory
    t0 = time.perf_counter()
    starts, lengths = plan_grains(total, k)
    t_plan = (time.perf_counter() - t0) * 1e6
    imb = max(lengths) / max(1, min(lengths))
    row("grains_plan_4096", t_plan,
        f"C({n},{m})={total} imbalance={imb:.6f}")
    t = _timeit(lambda: [unrank_py(starts[i], n, m)
                         for i in range(0, k, k // 64)], number=1)
    row("grains_start_unrank", t / 64,
        "per grain-start (host bigint, no width limit)")


# ------------------------------------------------------------- det serving
def bench_serve(num=128, max_m=4, max_n=12):
    """Batched-determinant serving throughput: synchronous drain vs the
    async pipelined DetQueue (stage/complete overlap + dynamic
    re-bucketing) on one mixed-shape queue, plus an open-loop Poisson
    arrival pass with admission control (shed/backlog behavior)."""
    try:
        from benchmarks.perf_serve import measure, measure_poisson
    except ImportError:  # direct-script run: sys.path[0] is benchmarks/
        from perf_serve import measure, measure_poisson
    r = measure(num, max_m, max_n, max_batch=32, repeat=2)
    row("det_serve_sync_drain", r["sync_s"] * 1e6 / num,
        f"per-mat; {r['sync_mats_per_s']:.0f} mats/s")
    row("det_serve_async_pipeline", r["async_s"] * 1e6 / num,
        f"per-mat; {r['async_mats_per_s']:.0f} mats/s "
        f"overlap_speedup={r['speedup']:.2f}x "
        f"merged={r['merged_requests']}")
    p = measure_poisson(num, rate=500.0, max_m=max_m, max_n=max_n,
                        max_batch=32, max_pending=32)
    row("det_serve_poisson_loadshed", p["latency_p50_ms"] * 1e3,
        f"p50 sojourn; offered={p['rate_offered']:.0f}/s "
        f"served={p['served_per_s']:.0f}/s shed={p['shed']} "
        f"({p['shed_frac']:.0%}) backlog_peak={p['backlog_peak']} "
        f"p99={p['latency_p99_ms']:.1f}ms")


def bench_front(num=96, workers=2):
    """Multi-worker bucket-routing front (DetFront) vs the in-process
    queue on a head-shape Poisson workload — the per-commit trace of the
    serving tier's horizontal-scale seam (see DESIGN_FRONT.md; CPU
    numbers on small hosts mostly show the routing/IPC overhead, the
    scaling itself needs > workers cores)."""
    try:
        from benchmarks.perf_serve import measure_front
    except ImportError:  # direct-script run: sys.path[0] is benchmarks/
        from perf_serve import measure_front
    rows = {r["tier"]: r
            for r in measure_front(num, workers, repeat=1,
                                   socket_loopback=True)}
    for tier in ("queue", f"front_w{workers}", f"front_shm_w{workers}",
                 f"front_sock_w{workers}"):
        r = rows[tier]
        row(f"det_{tier}", r["wall_s"] * 1e6 / num,
            f"per-mat; {r['mats_per_s']:.0f} mats/s "
            f"p50={r['p50_ms']:.1f}ms p99={r['p99_ms']:.1f}ms "
            f"vs_drain={r['speedup_vs_drain']:.2f}x")


def bench_hotpath():
    """Single-host hot-path legs, priced in isolation (the floors live
    in perf_serve full runs; these rows put the numbers on disk).  The
    shm row is payload-bound on purpose — large degenerate matrices make
    worker compute ~zero, so the delta is pure transport — and the combo
    row is the batched kernel at serving depth, where the combo-reuse
    grid pays unranking once per rank tile instead of once per matrix."""
    try:
        from benchmarks.perf_serve import (measure_combo_kernel,
                                           measure_shm_overhead)
    except ImportError:  # direct-script run: sys.path[0] is benchmarks/
        from perf_serve import measure_combo_kernel, measure_shm_overhead
    s = measure_shm_overhead(num=64, repeat=2)
    row("det_front_shm_overhead", s["shm_us_per_mat"],
        f"per-mat shm ring; local(pickle)={s['local_us_per_mat']:.0f}us "
        f"payload={s['payload_mb']:.0f}MB overhead_cut="
        f"{s['speedup']:.2f}x")
    k = measure_combo_kernel(repeat=5)
    row("det_batched_combo_kernel", k["combo_us_per_mat"],
        f"per-mat B={k['batch']} shape={k['shape'][0]}x{k['shape'][1]}; "
        f"bygrid={k['bygrid_us_per_mat']:.0f}us "
        f"speedup={k['speedup']:.2f}x")


def bench_front_autoscale(num=48, max_workers=2):
    """Elastic pool trace: the SLO autoscaler growing a 1-worker front
    toward ``max_workers`` under Poisson load and draining it back once
    the queue empties (launch/autoscale.py; the membership behavior is
    gated by perf_serve's --autoscale asserts, this row records what it
    cost)."""
    try:
        from benchmarks.perf_serve import measure_autoscale
    except ImportError:  # direct-script run: sys.path[0] is benchmarks/
        from perf_serve import measure_autoscale
    static, elastic = measure_autoscale(num, max_workers)
    row("det_front_autoscale", elastic["wall_s"] * 1e6 / num,
        f"per-mat; {elastic['mats_per_s']:.0f} mats/s "
        f"scaled_up={elastic['scaled_up']} "
        f"scaled_down={elastic['scaled_down']} "
        f"final_workers={elastic['final_workers']} "
        f"shed={elastic['shed']} (static_w1 {static['mats_per_s']:.0f} "
        f"mats/s shed={static['shed']})")


def bench_join_warmstart():
    """Fleet warm-start priced (DESIGN_PERSIST.md): a real ``det_serve
    --join`` subprocess dialing into a 1-worker front, clocked from
    spawn to admission — once compiling every live plan family cold,
    once warmed from a populated plan store (metadata prefill + the XLA
    compilation cache the store houses).  Identical startup and tracing
    on both sides; the delta is the compile work the store removes."""
    try:
        from benchmarks.perf_serve import measure_join_warmstart
    except ImportError:  # direct-script run: sys.path[0] is benchmarks/
        from perf_serve import measure_join_warmstart
    r = measure_join_warmstart()
    row("det_join_warmstart", r["warm_join_s"] * 1e6,
        f"store-warm join-to-admission; cold={r['cold_join_s']:.2f}s "
        f"warm={r['warm_join_s']:.2f}s speedup={r['speedup']:.2f}x "
        f"families={r['families']} "
        f"joiner_store_hits={r['warm_store_hits']}")


# ----------------------------------------------------------- plan/execute
def bench_engine(m=3, n=10, cap=16, shapes=((1, 6), (2, 7), (3, 9), (4, 11))):
    """DetEngine plan/execute split: what planning costs cold (validate +
    Pascal table + AOT lowering), what a cached plan lookup costs on the
    dispatch hot path, and that LRU eviction + re-plan stays sane for
    long-tail shape traffic."""
    from repro.core import DetEngine
    rng = np.random.default_rng(3)
    As = jnp.asarray(rng.normal(size=(cap, m, n)).astype(np.float32))

    eng = DetEngine(max_plans=64)
    t0 = time.perf_counter()
    plan = eng.plan(m, n, capacity=cap)
    t_cold = (time.perf_counter() - t0) * 1e6
    row("det_engine_plan_cold", t_cold,
        f"m={m} n={n} cap={cap} validate+table+AOT-lower")
    t = _timeit(lambda: eng.plan(m, n, capacity=cap), number=200)
    row("det_engine_plan_cached", t / 200, "LRU hit on the dispatch path")
    from repro.core.engine import _donation_supported
    t = _timeit(lambda: jax.block_until_ready(plan(As)))
    row("det_engine_exec_aot", t / cap,
        f"per-mat; cap={cap} AOT executable "
        f"donated={_donation_supported()}")

    lru = DetEngine(max_plans=2)
    t0 = time.perf_counter()
    for _ in range(3):  # 4 shapes through a 2-plan cache: every plan misses
        for (mm, nn) in shapes:
            lru.plan(mm, nn, capacity=4)
    t_churn = (time.perf_counter() - t0) * 1e6 / (3 * len(shapes))
    info = lru.cache_info()
    row("det_engine_lru_replan", t_churn,
        f"per-plan under eviction churn; evictions={info['evictions']} "
        f"size={info['size']}/{info['max_plans']}")


# ---------------------------------------------- derived kernel roofline args
def bench_fused_ai(m=8, n=32):
    """Arithmetic intensity of the fused kernel per §Roofline: FLOPs per
    HBM byte.  HBM traffic is only A + the Pascal table (replicated,
    amortized over the whole grid) + the (1,1) accumulator — ranks are
    generated from the grid index, minors live in VMEM only."""
    flops_per_rank = 2 * m * m * n + (2 / 3) * m ** 3 + 4 * m * n
    hbm_bytes_total = m * n * 4 + (n + 1) * (m + 1) * 4 + 4
    ranks = min(comb(n, m), 10 ** 6)
    ai = flops_per_rank * ranks / hbm_bytes_total
    row("fused_kernel_arith_intensity", 0.0,
        f"flops/rank={flops_per_rank:.0f} AI@1Mranks={ai:.2e} flop/B "
        "(v5e ridge ~240 flop/B => compute-bound)")


def machine_info() -> dict:
    """The facts needed to compare two BENCH_*.json artifacts honestly:
    same box or not, same backend or not, same jax or not."""
    return {
        "platform": platform.platform(),
        "python": platform.python_version(),
        "cpu_count": os.cpu_count(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "tcmalloc": "tcmalloc" in os.environ.get("LD_PRELOAD", ""),
    }


def save_bench(tag: str, reps: int, samples: dict[str, list[tuple[float, str]]]
               ) -> Path:
    """Write ``benchmarks/BENCH_<tag>.json`` — and a copy at the repo
    root — with machine info + per-row medians over ``reps`` full-suite
    repetitions.  Committed artifacts put the perf trajectory on disk
    instead of in commit messages (ROADMAP "priced on disk"); the root
    copy keeps the latest trajectory next to README.md where the
    benchmark table points (README "Benchmark trajectory")."""
    rows = []
    for name, vals in samples.items():
        us = statistics.median(v for v, _ in vals)
        rows.append({"name": name, "us_per_call": round(us, 3),
                     "derived": vals[-1][1]})
    out = {"tag": tag, "reps": reps, "machine": machine_info(), "rows": rows}
    text = json.dumps(out, indent=1) + "\n"
    path = Path(__file__).resolve().parent / f"BENCH_{tag}.json"
    path.write_text(text)
    root_path = path.parents[1] / f"BENCH_{tag}.json"
    root_path.write_text(text)
    print(f"saved {path} (+ {root_path})")
    return path


def run_suite() -> None:
    bench_unrank()
    bench_minor_det()
    bench_radic()
    bench_grains()
    bench_engine()
    bench_serve()
    bench_front()
    bench_hotpath()
    bench_front_autoscale()
    bench_join_warmstart()
    bench_fused_ai()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--save", metavar="TAG", default=None,
                    help="write benchmarks/BENCH_<TAG>.json (machine info "
                         "+ per-row medians) after the run")
    ap.add_argument("--reps", type=int, default=1,
                    help="full-suite repetitions; --save records the "
                         "per-row median across them (default 1)")
    args = ap.parse_args(argv)
    samples: dict[str, list[tuple[float, str]]] = {}
    for rep in range(max(1, args.reps)):
        ROWS.clear()
        print("name,us_per_call,derived")
        run_suite()
        for name, us, derived in ROWS:
            samples.setdefault(name, []).append((us, derived))
    if args.save:
        save_bench(args.save, max(1, args.reps), samples)


if __name__ == "__main__":
    main()
