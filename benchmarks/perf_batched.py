"""§Perf — batched multi-matrix dispatch vs one-matrix-at-a-time loop.

The batched front-end amortizes per-call costs over B matrices: one
dispatch, one rank-space walk (unranking and signs are computed once per
chunk and shared across the batch), one result transfer.  The loop pays
B dispatches and B redundant unranking walks.  Both sides are jit-warm
(compile time excluded), so the gap below is steady-state serving
throughput, which is what the ``det_serve`` driver cares about.

  PYTHONPATH=src python -m benchmarks.perf_batched
"""

from __future__ import annotations

import timeit

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comb, radic_det, radic_det_batched
from repro.launch.det_serve import drain_queue, _random_queue

M, N = 4, 12
CHUNK = 512
BATCHES = (1, 4, 16, 64)


def _wall(fn, number=3, repeat=3):
    fn()  # warm (compile)
    return min(timeit.repeat(fn, number=number, repeat=repeat)) / number


def main():
    rng = np.random.default_rng(0)
    print(f"# batched perf: m={M} n={N} C(n,m)={comb(N, M)} chunk={CHUNK}")
    print("B,loop_s,batched_s,speedup,loop_mats_per_s,batched_mats_per_s")
    for B in BATCHES:
        As = jnp.asarray(rng.normal(size=(B, M, N)).astype(np.float32))
        mats = [As[i] for i in range(B)]

        def loop():
            return [jax.block_until_ready(radic_det(A, chunk=CHUNK))
                    for A in mats]

        def batched():
            return jax.block_until_ready(radic_det_batched(As, chunk=CHUNK))

        # numerics: batched == loop
        got = np.asarray(batched())
        want = np.array([float(x) for x in loop()])
        assert np.allclose(got, want, rtol=1e-4, atol=1e-5), (got, want)

        t_loop = _wall(loop)
        t_bat = _wall(batched)
        print(f"{B},{t_loop:.4f},{t_bat:.4f},{t_loop / t_bat:.2f},"
              f"{B / t_loop:.1f},{B / t_bat:.1f}")

    # heterogeneous queue: bucketed batcher vs naive per-matrix loop
    queue = _random_queue(48, 4, 10, seed=1)

    def naive():
        return [float(jax.block_until_ready(
            radic_det(jnp.asarray(q), chunk=CHUNK))) for q in queue]

    def bucketed():
        return drain_queue(queue, chunk=CHUNK, max_batch=32)[0]

    got, want = bucketed(), naive()
    assert np.allclose(got, want, rtol=1e-4, atol=1e-5)
    t_naive = _wall(naive, number=1)
    t_buck = _wall(bucketed, number=1)
    print(f"queue48_hetero,{t_naive:.4f},{t_buck:.4f},"
          f"{t_naive / t_buck:.2f},{48 / t_naive:.1f},{48 / t_buck:.1f}")


if __name__ == "__main__":
    main()
