"""Roofline table generator — reads the dry-run JSON artifacts and emits
the per-(arch × shape) three-term analysis for EXPERIMENTS.md §Roofline.

    compute term    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory term     = HLO_bytes_per_device / HBM_bw_per_chip
    collective term = collective_bytes_per_device / ICI_bw_per_link

(cost_analysis is per-partition after SPMD, so dividing by per-chip peaks
is the same as the global formula divided by `chips`.)

Hardware constants (TPU v5e target): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import glob
import json
import os
import sys

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
ICI_BW = 50e9             # B/s / link

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun")

SUGGEST = {
    "compute": "raise MXU utilization: larger per-chip tiles (less TP), "
               "bf16 everywhere, fewer remat recomputes",
    "memory": "cut HBM traffic: fuse/flash attention, bf16 master copies, "
              "smaller logits dtype, better remat policy",
    "collective": "cut wire bytes: reduce-scatter instead of all-reduce, "
                  "overlap with compute, gradient compression, shrink TP "
                  "degree for this shape",
}


def load(result_dir=RESULTS, mesh="pod_16x16"):
    rows = []
    for p in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        rec = json.load(open(p))
        if rec.get("mesh") != mesh:
            continue
        rows.append(rec)
    return rows


def terms(rec) -> dict | None:
    if "hlo_flops_per_device" not in rec:
        return None
    ct = rec["hlo_flops_per_device"] / PEAK_FLOPS
    mt = rec["hlo_bytes_per_device"] / HBM_BW
    lt = rec["collective_bytes_per_device"] / ICI_BW
    dom = max(("compute", ct), ("memory", mt), ("collective", lt),
              key=lambda kv: kv[1])
    model_pd = rec["model_flops_global"] / rec["n_chips"]
    return {
        "compute_s": ct, "memory_s": mt, "collective_s": lt,
        "dominant": dom[0], "dominant_s": dom[1],
        "roofline_fraction": ct / dom[1] if dom[1] > 0 else 0.0,
        "useful_ratio": model_pd / rec["hlo_flops_per_device"]
        if rec["hlo_flops_per_device"] else 0.0,
    }


def markdown_table(rows) -> str:
    out = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
           "bottleneck | roofline frac | 6ND/HLO | fits 16G |",
           "|---|---|---|---|---|---|---|---|---|"]
    for rec in rows:
        if "skipped" in rec:
            out.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                       f"skipped: {rec['skipped'][:40]}… | — | — | — |")
            continue
        if "error" in rec:
            out.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                       f"ERROR | — | — | — |")
            continue
        t = terms(rec)
        if t is None:
            continue
        out.append(
            f"| {rec['arch']} | {rec['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | "
            f"{t['dominant']} | {t['roofline_fraction']:.2f} | "
            f"{t['useful_ratio']:.2f} | "
            f"{'yes' if rec.get('fits_hbm_16g') else 'NO'} |")
    return "\n".join(out)


def main():
    result_dir = sys.argv[1] if len(sys.argv) > 1 else RESULTS
    rows = load(result_dir)
    print(markdown_table(rows))
    print()
    # highlight the three hillclimb candidates
    scored = [(r, terms(r)) for r in rows
              if "error" not in r and "skipped" not in r and terms(r)]
    if scored:
        worst = min(scored, key=lambda rt: rt[1]["roofline_fraction"])
        collb = max(scored, key=lambda rt: rt[1]["collective_s"]
                    / max(rt[1]["dominant_s"], 1e-12))
        print(f"worst roofline fraction: {worst[0]['arch']}"
              f" × {worst[0]['shape']} ({worst[1]['roofline_fraction']:.2f},"
              f" {worst[1]['dominant']}-bound)")
        print(f"most collective-bound:   {collb[0]['arch']}"
              f" × {collb[0]['shape']}"
              f" (coll={collb[1]['collective_s']:.3e}s)")
        for kind in ("compute", "memory", "collective"):
            n = sum(1 for _, t in scored if t["dominant"] == kind)
            print(f"{kind}-bound cells: {n}")


if __name__ == "__main__":
    main()
