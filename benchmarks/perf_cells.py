"""§Perf hillclimb driver: re-lower chosen (arch × shape) cells with one
optimization applied at a time, measure the roofline-term deltas against
the baseline dry-run artifacts, and write a markdown iteration log.

Each variant is an independent dry-run compile (same mesh, same inputs) —
the "measurement" at dry-run scale is the compiled artifact: HLO FLOPs,
bytes accessed, collective operand bytes, and buffer-assignment peak
(`temp+args`), exactly the §Roofline terms.

  PYTHONPATH=src python -m benchmarks.perf_cells --cell arctic
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")

# (name, arch, shape, variants) — variants applied INDIVIDUALLY, then the
# best combination as "combo".
PLANS = {
    "arctic": {
        "arch": "arctic-480b", "shape": "train_4k",
        "why": "worst roofline fraction of the big training cells "
               "(memory-bound, 0.02); also carries the MoE dispatch story",
        "variants": {
            "onehot_dispatch": {"moe_impl": "onehot"},
            "flash_attn": {"attn_chunk": 1024},
            "chunked_ce": {"loss_chunk": 1024},
            "remat_dots": {"remat_policy": "dots"},
            "seq_shard": {"seq_shard": True},
            "combo": {"attn_chunk": 1024, "loss_chunk": 1024,
                      "seq_shard": True},
        },
    },
    "llama405": {
        "arch": "llama3-405b", "shape": "train_4k",
        "why": "most representative of the paper's technique: maximal "
               "DP-grain decomposition + one terminal reduction is "
               "exactly the 405B data-parallel training shape; also the "
               "flagship absolute-scale cell",
        "variants": {
            "flash_attn": {"attn_chunk": 1024},
            "chunked_ce": {"loss_chunk": 1024},
            "remat_dots": {"remat_policy": "dots"},
            "seq_shard": {"seq_shard": True},
            "combo": {"attn_chunk": 1024, "loss_chunk": 1024,
                      "seq_shard": True},
            "combo_dots": {"attn_chunk": 1024, "loss_chunk": 1024,
                           "seq_shard": True, "remat_policy": "dots"},
        },
    },
    "llama405_r2": {
        "arch": "llama3-405b", "shape": "train_4k",
        "why": "round 2 on the winner (combo = flash+chunked_ce+seq_shard)",
        "variants": {
            "combo_chunk4096": {"attn_chunk": 4096, "loss_chunk": 1024,
                                "seq_shard": True},
            "combo_chunk512": {"attn_chunk": 512, "loss_chunk": 1024,
                               "seq_shard": True},
            "combo_no_ce": {"attn_chunk": 1024, "seq_shard": True},
        },
    },
    "arctic_r2": {
        "arch": "arctic-480b", "shape": "train_4k",
        "why": "round 2: grouped-onehot dispatch won round 1; compose",
        "variants": {
            "onehot_flash": {"moe_impl": "onehot", "attn_chunk": 1024},
            "onehot_flash_dots": {"moe_impl": "onehot", "attn_chunk": 1024,
                                  "remat_policy": "dots"},
            "onehot_g1024": {"moe_impl": "onehot", "moe_group_size": 1024},
            "onehot_g4096": {"moe_impl": "onehot", "moe_group_size": 4096},
            "onehot_flash_ce": {"moe_impl": "onehot", "attn_chunk": 1024,
                                "loss_chunk": 1024},
        },
    },
    "arctic_prefill_r2": {
        "arch": "arctic-480b", "shape": "prefill_32k",
        "why": "round 2: compose flash_attn (flops) + onehot (coll/bytes)",
        "variants": {
            "flash_onehot": {"attn_chunk": 1024, "moe_impl": "onehot"},
            "flash_onehot_g8k": {"attn_chunk": 1024, "moe_impl": "onehot",
                                 "moe_group_size": 8192},
        },
    },
    "mamba_decode_r2": {
        "arch": "mamba2-1.3b", "shape": "decode_32k",
        "why": "round 2: act-rule variant (round-1 run hit a patching bug)",
        "variants": {
            "no_inner_tp": {"_act_overrides": {"inner": None,
                                               "ssm_heads": None}},
        },
    },
    "r3": {
        "arch": "llama3-405b", "shape": "train_4k",
        "why": "round 3: follow the attn_chunk trend (512 beat 1024)",
        "variants": {
            "combo_chunk256": {"attn_chunk": 256, "loss_chunk": 1024,
                               "seq_shard": True},
        },
    },
    "arctic_r3": {
        "arch": "arctic-480b", "shape": "train_4k",
        "why": "round 3: smaller attn chunk on the arctic winner",
        "variants": {
            "onehot_flash512_ce": {"moe_impl": "onehot", "attn_chunk": 512,
                                   "loss_chunk": 1024},
        },
    },
    "arctic_prefill": {
        "arch": "arctic-480b", "shape": "prefill_32k",
        "why": "most collective-bound cell (collective term = 0.79 of "
               "the dominant term; mamba2 prefill ties at 0.78)",
        "variants": {
            "flash_attn": {"attn_chunk": 1024},
            "onehot_dispatch": {"moe_impl": "onehot"},
            "bigger_groups": {"moe_group_size": 8192},
            "cap_1.0": {"capacity_factor": 1.0},
            "combo": {"attn_chunk": 1024, "moe_group_size": 8192},
        },
    },
    "mamba_decode": {
        "arch": "mamba2-1.3b", "shape": "decode_32k",
        "why": "the one collective-bound cell in the baseline table",
        "variants": {
            "dus_cache": {"cache_update": "dus"},
            "bf16_state": {"ssm_state_dtype": "bfloat16"},
            "no_inner_tp": {"_act_overrides": {"inner": None,
                                               "ssm_heads": None}},
            "combo": {"cache_update": "dus",
                      "ssm_state_dtype": "bfloat16"},
        },
    },
}


def run(plan_name: str):
    from repro.launch.dryrun import RESULTS as DR, run_cell
    plan = PLANS[plan_name]
    outdir = os.path.join(RESULTS, "perf", plan_name)
    os.makedirs(outdir, exist_ok=True)
    base_path = os.path.join(
        DR, f"{plan['arch']}__{plan['shape']}__pod_16x16.json")
    base = json.load(open(base_path))
    print(f"== {plan_name}: {plan['arch']} × {plan['shape']} ==")
    print(f"baseline: flops={base['hlo_flops_per_device']:.3e} "
          f"bytes={base['hlo_bytes_per_device']:.3e} "
          f"coll={base['collective_bytes_per_device']:.3e} "
          f"temp={base['memory']['temp_size_in_bytes']/2**30:.1f}GiB")
    rows = [dict(base, variant="baseline")]
    for name, ov in plan["variants"].items():
        ov = dict(ov)
        act_ov = ov.pop("_act_overrides", None)
        if act_ov:  # rule-level variants need a patched make_rules
            rec = _run_with_act_rules(plan, name, act_ov, outdir)
        else:
            rec = run_cell(plan["arch"], plan["shape"], False, outdir,
                           overrides=ov or None, tag=f"__{name}")
        rec = dict(rec, variant=name)
        rows.append(rec)
        if "error" not in rec and "hlo_flops_per_device" in rec:
            print(f"  {name:16s} flops={rec['hlo_flops_per_device']:.3e}"
                  f" bytes={rec['hlo_bytes_per_device']:.3e}"
                  f" coll={rec['collective_bytes_per_device']:.3e}"
                  f" temp={rec['memory']['temp_size_in_bytes']/2**30:.1f}G")
    with open(os.path.join(outdir, "summary.json"), "w") as f:
        json.dump(rows, f, indent=1)


def _run_with_act_rules(plan, name, act_ov, outdir):
    """Variant that changes activation sharding rules, not the config."""
    import repro.launch.dryrun as dr_mod
    from repro.launch.dryrun import run_cell
    orig = dr_mod.make_rules

    def patched(cfg, mesh, **kw):
        rules = orig(cfg, mesh, **kw)
        act = dict(rules.act)
        act.update(act_ov)
        import dataclasses
        return dataclasses.replace(rules, act=act)

    dr_mod.make_rules = patched
    try:
        return run_cell(plan["arch"], plan["shape"], False, outdir,
                        tag=f"__{name}")
    finally:
        dr_mod.make_rules = orig


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(PLANS) + ["all"], default="all")
    args = ap.parse_args()
    for c in (PLANS if args.cell == "all" else [args.cell]):
        run(c)
