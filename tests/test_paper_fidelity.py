"""Fidelity tests against the paper's own worked artifacts (Section 4)."""

import numpy as np

from repro.core import (combinations_lex, combinatorial_addition, comb,
                        first_member, grain_sequence, last_member,
                        paper_table, rank_py, unrank_py)


def test_example_1():
    """q=49, n=8, m=5 -> B_49 = [2,5,6,7,8] (paper Example 1)."""
    assert combinatorial_addition(49, 8, 5) == (2, 5, 6, 7, 8)
    assert unrank_py(49, 8, 5) == (2, 5, 6, 7, 8)
    assert rank_py((2, 5, 6, 7, 8), 8, 5) == 49


def test_table_2_all_56_subsets():
    """The paper's Table 2: all C(8,5)=56 subsets in dictionary order."""
    combos = combinations_lex(8, 5)
    assert len(combos) == 56 == comb(8, 5)
    for q, c in enumerate(combos):
        assert combinatorial_addition(q, 8, 5) == c
    # spot-check the members the paper prints explicitly
    assert combos[0] == (1, 2, 3, 4, 5)      # B_0 (First Member)
    assert combos[11] == (1, 2, 4, 5, 7)     # B_11
    assert combos[49] == (2, 5, 6, 7, 8)     # B_49
    assert combos[55] == (4, 5, 6, 7, 8)     # B_55 (last member)


def test_paper_table_1_layout():
    """Table 1: entry (j, i) = C(i+j, j); last column = place weights."""
    T = paper_table(8, 5)          # rows j=0..4, cols i=1..3
    assert T.shape == (5, 3)
    assert T[4, 2] == comb(7, 4) == 35   # the weight used in Example 1
    assert T[3, 1] == comb(5, 3) == 10   # second stage of Example 1
    last_col = T[:, -1]
    weights = [comb(8 - 5 + j, j) for j in range(5)]
    assert list(last_col) == weights


def test_first_last_members():
    assert first_member(5) == (1, 2, 3, 4, 5)
    assert last_member(8, 5) == (4, 5, 6, 7, 8)


def test_grain_sequence_matches_lex_order():
    """Fig. 1 second listing: per-processor successor walk inside a grain."""
    combos = combinations_lex(9, 4)
    # grain of 10 starting at rank 37 (the paper's k-processor split)
    start = unrank_py(37, 9, 4)
    grain = grain_sequence(start, 10, 9)
    assert grain == combos[37:47]


def test_theorem_1_counts():
    """Theorem 1: number of ascending m-sequences == C(n, m)."""
    for n in range(1, 10):
        for m in range(1, n + 1):
            assert len(combinations_lex(n, m)) == comb(n, m)
