"""Fault-injection battery for the socket transport under DetFront.

Going over sockets is where serving correctness gets hard: partial
writes, dead peers, duplicated and delayed frames.  The battery injects
each failure class at the *frame* level (a :class:`FlakyTransport`
wrapping the real ``SocketTransport``) and asserts the three invariants
the transport seam promises:

* the front re-routes **deterministically** (stable hashing: the same
  victim's keys always land on the same survivor);
* futures **never hang** (every ``result(timeout=...)`` below is a
  liveness assertion — a stuck future fails the test, it doesn't wedge
  it);
* results stay **bit-identical** to a 1-process ``DetQueue`` under the
  pinned-capacity policy, faults and all.

Workers are real socket daemons: in-thread (`ThreadedWorkerServer`) for
the frame-mangling tests (full visibility, no spawn cost) and real
subprocess daemons for the SIGKILL-mid-flight proof — the socket
extension of the PR 4 process-sentinel kill test.
"""

import pickle
import signal
import time

import numpy as np
import pytest

from repro.launch import transport as T
from repro.launch.det_front import DetFront, PlanPlacer, route_key
from repro.launch.det_queue import BucketPolicy, DetQueue

CHUNK = 128
CAP = 8
PINNED = BucketPolicy(max_batch=CAP, mode="merge", pin_capacity=True)
# the front-battery heterogeneous pool, incl. one m > n degenerate
SHAPES = [(1, 4), (2, 5), (2, 6), (3, 7), (3, 9), (4, 10), (4, 2)]


def _mats(rng, num, shapes=SHAPES):
    out = []
    for _ in range(num):
        m, n = shapes[int(rng.integers(0, len(shapes)))]
        out.append(rng.normal(size=(m, n)).astype(np.float32))
    return out


def _queue_reference(mats, policy=PINNED):
    """The single-process ground truth for a request set."""
    with DetQueue(chunk=CHUNK, policy=policy) as q:
        dets, _ = q.serve(mats, timeout=300)
    return dets


def _static_owner(shape, workers=(0, 1), policy=PINNED):
    """Predict which worker id owns a shape *before* any front exists:
    placement is a pure function of (key, worker ids), which is exactly
    what lets a fault rule target the right victim at transport-build
    time — and is itself a determinism assertion."""
    placer = PlanPlacer(list(workers))
    return placer.assign(route_key(shape, policy, np.float32, False))


# ------------------------------------------------------------ flaky plumbing
class _FlakySocket:
    """A sendall-mangling shim over a real socket.  The link writes
    exactly one frame per ``sendall``, so ``rule(frame_index, data)``
    sees whole frames and returns the byte chunks actually sent —
    ``[]`` drops, ``[d, d]`` duplicates, ``[d[:k]]`` truncates."""

    def __init__(self, sock, rule):
        self._sock = sock
        self._rule = rule
        self._n = 0

    def sendall(self, data):
        self._n += 1
        for chunk in self._rule(self._n, data):
            self._sock.sendall(chunk)

    def recv(self, *args):
        return self._sock.recv(*args)

    def fileno(self):
        return self._sock.fileno()

    def shutdown(self, *args):
        return self._sock.shutdown(*args)

    def close(self):
        return self._sock.close()


class FlakyTransport(T.SocketTransport):
    """SocketTransport whose post-handshake streams are mangled by
    per-worker rules (handshakes stay clean by construction: the shim
    is installed by ``_finish``, after ready)."""

    def __init__(self, addresses, rules, **kwargs):
        super().__init__(addresses, **kwargs)
        self._rules = rules

    def _finish(self, sock, wid, addr):
        rule = self._rules.get(wid)
        return _FlakySocket(sock, rule) if rule is not None else sock


def _frame_msg(data):
    """Decode one whole frame's message (test-side peek for
    content-aware fault rules)."""
    return pickle.loads(data[10:])  # header: magic 2B + len 4B + crc 4B


def _servers(k):
    return [T.ThreadedWorkerServer() for _ in range(k)]


def _close_all(servers):
    for s in servers:
        s.close(timeout=10)


# ------------------------------------------------------------- clean loopback
def test_socket_front_bit_identical_to_queue(rng):
    """No faults: a front over two socket daemons is bit-identical to
    the 1-process DetQueue on the mixed-shape pool."""
    mats = _mats(rng, 30)
    want = _queue_reference(mats)
    servers = _servers(2)
    try:
        tr = T.SocketTransport([s.address for s in servers],
                               heartbeat_s=0.25)
        with DetFront(transport=tr, chunk=CHUNK, policy=PINNED) as front:
            got, stats = front.serve(mats, timeout=300)
    finally:
        _close_all(servers)
    assert got == want
    assert stats["front"]["worker_deaths"] == 0
    assert stats["total"]["completed"] == 30
    assert stats["front"]["degraded"] is False


def test_socket_front_head_shapes_bit_identical(rng):
    """The acceptance workload: head_shapes() (equal-work hot shapes)
    through a socket-loopback front matches the 1-process queue bit for
    bit."""
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.perf_serve import head_shapes
    shapes = head_shapes(max_m=4, target_ranks=120, per_m=2)
    assert shapes, "head_shapes returned no shapes at test scale"
    mats = _mats(rng, 24, shapes=shapes)
    want = _queue_reference(mats)
    servers = _servers(2)
    try:
        tr = T.SocketTransport([s.address for s in servers])
        with DetFront(transport=tr, chunk=CHUNK, policy=PINNED) as front:
            got, _ = front.serve(mats, timeout=300)
    finally:
        _close_all(servers)
    assert got == want


# ------------------------------------------------------------------ drops
def test_dropped_request_frames_reroute_without_hanging(rng):
    """Every request frame to the victim vanishes while its heartbeats
    keep flowing — the failure a pure heartbeat detector cannot see.
    The unacked-batch deadline must declare the victim dead and re-route
    to the survivor, bit-identically, with no future left hanging."""
    mats = [rng.normal(size=(3, 7)).astype(np.float32) for _ in range(12)]
    want = _queue_reference(mats)
    victim = _static_owner((3, 7))
    servers = _servers(2)
    try:
        tr = FlakyTransport([s.address for s in servers],
                            rules={victim: lambda i, d: []},
                            heartbeat_s=0.25)
        with DetFront(transport=tr, chunk=CHUNK, policy=PINNED,
                      ack_timeout_s=1.0) as front:
            assert front.owner_of((3, 7)) == victim
            futs = front.submit_many(mats)
            got = [f.result(timeout=300) for f in futs]
            stats = front.snapshot()
            assert front.alive_workers == [1 - victim]
    finally:
        _close_all(servers)
    assert got == want
    assert stats["front"]["worker_deaths"] == 1
    assert stats["front"]["rerouted"] == 12


# ------------------------------------------------------------- truncation
def test_truncated_frame_desyncs_peer_and_reroutes(rng):
    """The victim's first batch frame is cut in half; the next frame
    lands misaligned in its decoder (CRC mismatch -> FrameError), the
    daemon drops the session, the front sees EOF and re-routes — with
    the unacked deadline as the backstop for the half-frame that never
    errors (nothing further arrives to expose it)."""
    mats = [rng.normal(size=(3, 7)).astype(np.float32) for _ in range(10)]
    want = _queue_reference(mats)
    victim = _static_owner((3, 7))

    def truncate_first(i, d):
        return [d[: len(d) // 2]] if i == 1 else [d]

    servers = _servers(2)
    try:
        tr = FlakyTransport([s.address for s in servers],
                            rules={victim: truncate_first},
                            heartbeat_s=0.25)
        with DetFront(transport=tr, chunk=CHUNK, policy=PINNED,
                      ack_timeout_s=2.0) as front:
            futs = front.submit_many(mats[:5])
            time.sleep(0.2)
            futs += front.submit_many(mats[5:])  # exposes the desync
            got = [f.result(timeout=300) for f in futs]
            stats = front.snapshot()
    finally:
        _close_all(servers)
    assert got == want
    assert stats["front"]["worker_deaths"] == 1
    assert stats["front"]["rerouted"] > 0


# ------------------------------------------------------------ duplication
def test_duplicated_frames_are_idempotent(rng):
    """Every frame to both workers is sent twice.  Batch acks and
    responses are keyed (batch id / seq), so duplicates are absorbed:
    every seq appears on the poll stream exactly once, counters don't
    double, results stay bit-identical."""
    mats = _mats(rng, 20)
    want = _queue_reference(mats)
    dup = {0: lambda i, d: [d, d], 1: lambda i, d: [d, d]}
    servers = _servers(2)
    try:
        tr = FlakyTransport([s.address for s in servers], rules=dup,
                            heartbeat_s=0.25)
        with DetFront(transport=tr, chunk=CHUNK, policy=PINNED,
                      ack_timeout_s=5.0) as front:
            futs = front.submit_many(mats)
            by_seq = {}
            while len(by_seq) < len(mats):
                got = front.poll(timeout=60.0)
                assert got, "poll timed out with responses outstanding"
                for seq, val in got:
                    assert seq not in by_seq, "duplicate poll delivery"
                    by_seq[seq] = val
            stats = front.snapshot()
    finally:
        _close_all(servers)
    assert [by_seq[f.seq] for f in futs] == want
    assert stats["front"]["worker_deaths"] == 0
    assert stats["front"]["completed"] == 20


# ----------------------------------------------------------------- delay
def test_delayed_frames_all_resolve(rng):
    """Frames are delayed below the heartbeat deadline: nothing may be
    declared dead, nothing may hang, results stay bit-identical."""
    mats = _mats(rng, 16)
    want = _queue_reference(mats)

    def slow(i, d):
        time.sleep(0.03)
        return [d]

    servers = _servers(2)
    try:
        tr = FlakyTransport([s.address for s in servers],
                            rules={0: slow, 1: slow}, heartbeat_s=0.5)
        with DetFront(transport=tr, chunk=CHUNK, policy=PINNED,
                      ack_timeout_s=10.0) as front:
            got, stats = front.serve(mats, timeout=300)
    finally:
        _close_all(servers)
    assert got == want
    assert stats["front"]["worker_deaths"] == 0


# ---------------------------------------------------------- peer death
def test_socket_worker_sigkill_mid_flight_bit_identical(rng):
    """The PR 4 SIGKILL proof, extended over the wire: a real daemon
    subprocess is SIGKILLed with requests in flight; the front detects
    the torn connection, re-routes the orphans to the survivor daemon,
    and every request still matches the 1-process queue bit for bit."""
    mats = _mats(rng, 24)
    want = _queue_reference(mats)
    procs, addrs = [], []
    try:
        for _ in range(2):
            proc, addr = T.spawn_worker_daemon()
            procs.append(proc)
            addrs.append(addr)
        tr = T.SocketTransport(addrs, heartbeat_s=0.25)
        with DetFront(transport=tr, chunk=CHUNK, policy=PINNED) as front:
            victim = front.owner_of((3, 9))
            futs = front.submit_many(mats)
            procs[victim].send_signal(signal.SIGKILL)
            got = [f.result(timeout=300) for f in futs]
            stats = front.snapshot()
            assert front.alive_workers == [1 - victim]
    finally:
        for proc in procs:
            proc.kill()
            proc.wait(timeout=30)
    assert got == want
    assert stats["front"]["worker_deaths"] == 1
    assert stats["front"]["rerouted"] > 0
    assert stats["front"]["completed"] == 24


def test_total_socket_loss_fails_pending_without_hanging(rng):
    mats = [rng.normal(size=(3, 9)).astype(np.float32) for _ in range(6)]
    servers = _servers(1)
    try:
        tr = T.SocketTransport([servers[0].address], heartbeat_s=0.25)
        front = DetFront(transport=tr, chunk=CHUNK, policy=PINNED)
        try:
            futs = front.submit_many(mats)
            front.kill_worker(0)
            for f in futs:
                with pytest.raises(RuntimeError):
                    f.result(timeout=120)
            with pytest.raises(RuntimeError):
                front.submit(mats[0])
        finally:
            front.close()
    finally:
        _close_all(servers)


# ----------------------------------------------------------- reconnect
def _wait_alive(front, want, timeout=60.0):
    deadline = time.monotonic() + timeout
    while sorted(front.alive_workers) != sorted(want):
        assert time.monotonic() < deadline, \
            f"alive={front.alive_workers}, want {want}"
        time.sleep(0.05)


def test_reconnect_worker_rejoins_socket_pool(rng):
    """Graceful reconnect-and-reroute: after a socket peer death the
    front re-dials the same address (a fresh daemon session), the
    stable ring re-inserts the old arc, and the rejoined pool serves
    the same requests bit-identically."""
    mats = _mats(rng, 16)
    want = _queue_reference(mats)
    servers = [T.ThreadedWorkerServer(max_sessions=2) for _ in range(2)]
    try:
        tr = T.SocketTransport([s.address for s in servers],
                               heartbeat_s=0.25)
        with DetFront(transport=tr, chunk=CHUNK, policy=PINNED) as front:
            assert front.serve(mats, timeout=300)[0] == want
            victim = front.owner_of((3, 7))
            front.kill_worker(victim)
            _wait_alive(front, [1 - victim])
            assert front.reconnect_worker(victim) is True
            assert front.reconnect_worker(victim) is True  # idempotent
            assert sorted(front.alive_workers) == [0, 1]
            futs = front.submit_many(mats)
            got = [f.result(timeout=300) for f in futs]
            stats = front.snapshot()
    finally:
        _close_all(servers)
    assert got == want
    assert stats["front"]["worker_deaths"] == 1
    assert stats["front"]["workers_alive"] == 2


def test_reconnect_worker_respawns_local_process(rng):
    """The same rejoin over LocalTransport: the dead worker's process
    is respawned under its old id."""
    mats = _mats(rng, 12)
    want = _queue_reference(mats)
    with DetFront(workers=2, chunk=CHUNK, policy=PINNED) as front:
        victim = front.owner_of((3, 9))
        front.kill_worker(victim)
        _wait_alive(front, [1 - victim])
        assert front.reconnect_worker(victim) is True
        assert sorted(front.alive_workers) == [0, 1]
        got, stats = front.serve(mats, timeout=300)
    assert got == want
    assert stats["front"]["worker_deaths"] == 1


def test_reconnect_after_total_loss_restarts_the_stream(rng):
    """Total worker loss ends the response stream; a successful
    reconnect must restart it — submits work again and poll() delivers
    rather than reporting a dead end."""
    mats = [rng.normal(size=(2, 5)).astype(np.float32) for _ in range(6)]
    want = _queue_reference(mats)
    with DetFront(workers=1, chunk=CHUNK, policy=PINNED) as front:
        futs = front.submit_many(mats)
        front.kill_worker(0)
        for f in futs:
            with pytest.raises(RuntimeError):
                f.result(timeout=120)
        _wait_alive(front, [])
        assert front.reconnect_worker(0) is True
        futs = front.submit_many(mats)
        got = [f.result(timeout=300) for f in futs]
        by_seq = {}
        while not all(f.seq in by_seq for f in futs):
            polled = front.poll(timeout=60.0)
            assert polled or all(f.seq in by_seq for f in futs)
            by_seq.update(polled)
    assert got == want
    assert [by_seq[f.seq] for f in futs] == want


# ------------------------------------------------- degraded stats snapshot
def test_snapshot_degraded_when_worker_stops_answering(rng):
    """The satellite regression: a worker that dies (or goes deaf)
    between the liveness check and the stats reply must not make
    ``snapshot()`` raise or hang — it returns partial stats flagged
    ``degraded`` (here: the victim's stats request frames are dropped
    while everything else flows)."""
    mats = [rng.normal(size=(2, 5)).astype(np.float32) for _ in range(8)]
    victim = _static_owner((2, 5))

    def drop_stats(i, d):
        return [] if _frame_msg(d)[0] == "stats" else [d]

    servers = _servers(2)
    try:
        tr = FlakyTransport([s.address for s in servers],
                            rules={victim: drop_stats}, heartbeat_s=0.25)
        with DetFront(transport=tr, chunk=CHUNK, policy=PINNED) as front:
            futs = front.submit_many(mats)
            assert all(isinstance(f.result(timeout=300), float)
                       for f in futs)
            stats = front.snapshot(timeout=1.5)
            # serving still works after a degraded snapshot
            assert isinstance(
                front.submit(mats[0]).result(timeout=300), float)
    finally:
        _close_all(servers)
    assert stats["front"]["degraded"] is True
    assert victim not in stats["workers"]
    assert (1 - victim) in stats["workers"]


def test_snapshot_after_local_kill_never_raises(rng):
    """Local-transport leg of the same regression: SIGKILL a worker and
    immediately snapshot, racing the death detection — every outcome
    (report, missing report + degraded flag) must return, not raise."""
    with DetFront(workers=2, chunk=CHUNK, policy=PINNED) as front:
        fut = front.submit(rng.normal(size=(3, 7)).astype(np.float32))
        assert isinstance(fut.result(timeout=300), float)
        front.kill_worker(front.owner_of((3, 7)))
        stats = front.snapshot(timeout=10.0)
        assert set(stats) == {"front", "workers", "total"}
        deadline = time.monotonic() + 60
        while len(front.alive_workers) > 1:
            assert time.monotonic() < deadline
            time.sleep(0.05)
        stats = front.snapshot(timeout=30.0)
        assert stats["front"]["degraded"] is False
        assert len(stats["workers"]) == 1


# -------------------------------------------------------- shared-memory ring
def test_shm_ring_descriptor_round_trip():
    """In-process producer/consumer pair: every dtype/shape/layout round
    trips byte-exactly through the ring, and the consumer's release
    watermarks are the monotonic FIFO reclaim protocol promises."""
    rng = np.random.default_rng(3)
    ring = T.ShmRing(1 << 16)
    reader = T.ShmRingReader(ring.name)
    try:
        payloads = [
            rng.normal(size=(3, 9)).astype(np.float32),
            rng.normal(size=(4, 2)),                        # float64
            rng.integers(0, 100, size=(7,), dtype=np.int64),
            np.asfortranarray(rng.normal(size=(5, 6)).astype(np.float32)),
            rng.normal(size=(2, 3, 4)).astype(np.float32),
        ]
        descs = [ring.write(p) for p in payloads]
        assert all(T.is_shm_descriptor(d) for d in descs)
        releases = [d[2] for d in descs]
        assert releases == sorted(releases)                 # FIFO, monotonic
        for p, d in zip(payloads, descs):
            got = reader.read(d)
            np.testing.assert_array_equal(got, np.ascontiguousarray(p))
            assert got.dtype == p.dtype
    finally:
        reader.close()
        ring.dispose()


def test_shm_ring_full_then_reclaim():
    """A full ring returns None (the inline-fallback signal), and space
    comes back exactly when the consumer publishes its watermark —
    including an allocation that skips the wrap fragment."""
    ring = T.ShmRing(256)
    reader = T.ShmRingReader(ring.name)
    try:
        a = np.arange(24, dtype=np.float32)   # 96 B -> 128 B slot
        b = np.arange(6, dtype=np.float32)    # 24 B -> 64 B slot
        d1 = ring.write(a)
        d2 = ring.write(b)
        assert d1 is not None and d2 is not None
        # 192/256 B used; a third 128 B slot would straddle the end and
        # the post-skip position exceeds the unreleased window -> None
        assert ring.write(a) is None
        # oversized payloads never fit, full or empty
        assert ring.write(np.zeros(512, np.float32)) is None
        np.testing.assert_array_equal(reader.read(d1), a)
        np.testing.assert_array_equal(reader.read(d2), b)
        # head published -> the wrap-skipping retry lands at offset 0
        d3 = ring.write(a)
        assert d3 is not None and d3[1] == 0
        np.testing.assert_array_equal(reader.read(d3), a)
    finally:
        reader.close()
        ring.dispose()


def test_shm_ring_disposed_write_returns_none():
    """dispose() is idempotent and flips write() to the inline fallback
    instead of touching a dead mapping."""
    ring = T.ShmRing(256)
    assert ring.write(np.zeros(4, np.float32)) is not None
    ring.dispose()
    ring.dispose()
    assert ring.write(np.zeros(4, np.float32)) is None


def test_shm_front_bit_identical_to_queue(rng):
    """The shm fast path is still the same determinant service: a mixed
    shape stream (degenerate m > n included) through ``DetFront(shm=True)``
    matches the 1-process queue bit for bit."""
    mats = _mats(rng, 24)
    want = _queue_reference(mats)
    with DetFront(workers=2, chunk=CHUNK, policy=PINNED, shm=True) as front:
        assert all(l.startswith("shm(") for l in front.describe_links())
        got, stats = front.serve(mats, timeout=300)
    assert got == want
    assert stats["front"]["completed"] == 24
    assert stats["front"]["worker_deaths"] == 0


def test_shm_tiny_ring_inline_fallback_bit_identical(rng):
    """A ring too small for most payloads degrades per payload to the
    inline pickle path — a mixed descriptor/inline stream must stay
    bit-identical (correctness never depends on ring capacity)."""
    mats = _mats(rng, 20)
    want = _queue_reference(mats)
    tr = T.ShmTransport(2, ring_bytes=64)  # one 64 B slot: most fall back
    with DetFront(transport=tr, chunk=CHUNK, policy=PINNED) as front:
        got, _ = front.serve(mats, timeout=300)
    assert got == want


def test_shm_worker_sigkill_mid_flight_bit_identical(rng):
    """The PR 4 SIGKILL proof on the shm path: a worker dies with
    descriptors in flight (its ring slots are never released), the
    orphans re-route to the survivor, results stay bit-identical."""
    mats = _mats(rng, 24)
    want = _queue_reference(mats)
    with DetFront(workers=2, chunk=CHUNK, policy=PINNED, shm=True) as front:
        victim = front.owner_of((3, 9))
        futs = front.submit_many(mats)
        front.kill_worker(victim)
        got = [f.result(timeout=300) for f in futs]
        stats = front.snapshot()
        assert front.alive_workers == [1 - victim]
    assert got == want
    assert stats["front"]["worker_deaths"] == 1
    assert stats["front"]["completed"] == 24


def test_shm_reconnect_respawns_with_fresh_ring(rng):
    """Rejoin over ShmTransport: the respawned worker gets a brand-new
    ring (a dead worker's unreleased slots die with its link), and the
    rejoined pool serves bit-identically."""
    mats = _mats(rng, 12)
    want = _queue_reference(mats)
    with DetFront(workers=2, chunk=CHUNK, policy=PINNED, shm=True) as front:
        victim = front.owner_of((3, 9))
        front.kill_worker(victim)
        _wait_alive(front, [1 - victim])
        assert front.reconnect_worker(victim) is True
        assert sorted(front.alive_workers) == [0, 1]
        got, stats = front.serve(mats, timeout=300)
    assert got == want
    assert stats["front"]["worker_deaths"] == 1
