"""Direct unit tests for the jax-version compat seam.

The subprocess mesh tests (test_distributed / test_pipeline) prove the
end-to-end paths, but bury any compat regression inside an ``assert "OK"
in stdout``.  These tests exercise both historical shard_map spellings
in-process via monkeypatch so a translation bug fails with a readable
message, plus the pvary/pcast/no-op ladder and the reduction helpers on
a real single-device mesh.
"""

import functools
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel import compat


def _ident(x):
    return x


def test_resolves_installed_jax():
    fn, src = compat._native_shard_map()
    assert callable(fn)
    assert src in ("jax.shard_map", "jax.experimental.shard_map.shard_map")
    assert compat.native_shard_map_source() == src


def test_new_spelling_gets_check_vma(monkeypatch):
    captured = {}

    def fake(f, *, mesh, in_specs, out_specs, check_vma=True):
        captured.update(f=f, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_vma=check_vma)
        return "mapped"

    monkeypatch.setattr(compat, "_native_shard_map",
                        lambda: (fake, "jax.shard_map"))
    out = compat.shard_map(_ident, mesh="M", in_specs=(P(),),
                           out_specs=P(), check_vma=False)
    assert out == "mapped"
    assert captured["check_vma"] is False
    assert captured["mesh"] == "M" and captured["f"] is _ident

    # old-spelling kwarg from a caller is translated forward
    compat.shard_map(_ident, mesh="M", in_specs=(), out_specs=P(),
                     check_rep=False)
    assert captured["check_vma"] is False


def test_old_spelling_gets_check_rep(monkeypatch):
    captured = {}

    def fake(f, mesh, in_specs, out_specs, check_rep=True,
             auto=frozenset()):
        captured.update(f=f, mesh=mesh, check_rep=check_rep)
        return "mapped"

    monkeypatch.setattr(compat, "_native_shard_map",
                        lambda: (fake, "jax.experimental.shard_map.shard_map"))
    out = compat.shard_map(_ident, mesh="M", in_specs=(P(),),
                           out_specs=P(), check_vma=False)
    assert out == "mapped"
    assert captured["check_rep"] is False
    assert "check_vma" not in inspect.signature(fake).parameters


def test_unknown_check_param_is_dropped(monkeypatch):
    def fake(f, *, mesh, in_specs, out_specs):  # neither spelling
        return "mapped"

    monkeypatch.setattr(compat, "_native_shard_map",
                        lambda: (fake, "jax.shard_map"))
    assert compat.shard_map(_ident, mesh="M", in_specs=(),
                            out_specs=P(), check_vma=False) == "mapped"


def test_both_check_spellings_rejected():
    with pytest.raises(TypeError, match="not both"):
        compat.shard_map(_ident, mesh="M", in_specs=(), out_specs=P(),
                         check_vma=False, check_rep=False)


def test_check_flag_omitted_means_native_default(monkeypatch):
    captured = {}

    def fake(f, *, mesh, in_specs, out_specs, check_vma=True):
        captured["check_vma"] = check_vma
        return "mapped"

    monkeypatch.setattr(compat, "_native_shard_map",
                        lambda: (fake, "jax.shard_map"))
    compat.shard_map(_ident, mesh="M", in_specs=(), out_specs=P())
    assert captured["check_vma"] is True  # native default untouched


def test_pvary_prefers_pvary_then_pcast(monkeypatch):
    calls = []
    monkeypatch.setattr(jax.lax, "pvary",
                        lambda x, axes: calls.append(("pvary", axes)) or x,
                        raising=False)
    assert compat.pvary(3, ("a", "b")) == 3
    assert calls == [("pvary", ("a", "b"))]

    monkeypatch.delattr(jax.lax, "pvary", raising=False)
    monkeypatch.setattr(
        jax.lax, "pcast",
        lambda x, axes, to: calls.append(("pcast", axes, to)) or x,
        raising=False)
    assert compat.pvary(3, ("a",)) == 3
    assert calls[-1] == ("pcast", ("a",), "varying")

    monkeypatch.delattr(jax.lax, "pcast", raising=False)
    assert compat.pvary(3, ("a",)) == 3  # identity on jax 0.4.x
    assert compat.pvary(7, ()) == 7      # no axes -> always identity


def test_psum_scalar_and_axis_size_on_real_mesh():
    mesh = Mesh(np.array(jax.devices()[:1]), ("w",))

    @functools.partial(compat.shard_map, mesh=mesh,
                       in_specs=(P("w"),), out_specs=P())
    def total(x):
        return compat.psum_scalar(jnp.sum(x), ("w",))

    assert float(total(jnp.arange(4.0))) == 6.0

    @functools.partial(compat.shard_map, mesh=mesh,
                       in_specs=(P("w"),), out_specs=P())
    def size(x):
        return jnp.zeros(()) + compat.axis_size("w")

    assert int(size(jnp.arange(2.0))) == 1
    assert compat.psum_scalar(5, ()) == 5  # no axes -> identity


def test_no_direct_shard_map_access_outside_compat():
    """Acceptance: jax.shard_map spellings only inside parallel/compat.

    Delegates to reprolint's compat-seam pass (tools/lint), which
    supersedes the old textual grep: the AST pass also catches aliased
    imports, ``from``-imports, resolved attribute chains and ``getattr``
    spellings, and — unlike the grep — does not false-positive on
    docstrings that merely *mention* the forbidden names.
    """
    import pathlib
    import sys
    root = pathlib.Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(root))
    from tools.lint import lint_paths
    from tools.lint.passes import CompatSeamPass
    findings, n_files = lint_paths([str(root / "src")], [CompatSeamPass()])
    assert n_files > 0
    assert not findings, "\n".join(f.render() for f in findings)
