"""The differentiable Radic determinant (DESIGN_GRAD.md) under test.

Ground truth is established once, in float64 (a subprocess, since
tier-1 runs with x64 off): ``jax.grad(radic_det)`` against central
finite differences, and against ``jax.grad(jnp.linalg.det)`` on square
inputs (m == n has exactly one subset with sign +1, so the two
determinants coincide — Corollary 2).  Every other backend and serving
path is then checked against the jnp VJP, which transfers the FD
verification: Pallas at kernel (f32) precision, the mesh evaluator in
the forced-8-device subprocess, the AOT plan program bit-exactly, and
the DetQueue/DetFront gradient request paths.

Bit-identity notes baked into asserts below: the AOT-lowered grad
program and the traced ``jax.vjp`` route share statics and program, so
they must agree to the bit; the queue pads grad batches with ct = 0
slots, so padding must never perturb (or NaN) real slots; scaling the
cotangent *inside* the VJP is the serving semantic — multiplying
``jax.grad``'s result afterwards agrees only to rounding, which is why
comparisons here pull the ct through ``jax.vjp``.
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import radic_det, radic_det_batched, aot_compile_batched
from repro.core.engine import default_engine
from repro.launch.det_queue import (BucketPolicy, DetQueue, Request,
                                    plan_buckets)

REPO = Path(__file__).resolve().parents[1]


# ------------------------------------------------------- f64 ground truth
F64_GRAD = textwrap.dedent("""
    import os
    os.environ["JAX_ENABLE_X64"] = "True"
    import numpy as np, jax, jax.numpy as jnp
    assert jax.config.jax_enable_x64
    from repro.core import radic_det, radic_det_batched
    rng = np.random.default_rng(0)
    # central finite differences, elementwise, f64
    for (m, n) in [(1, 4), (2, 5), (3, 7), (3, 3)]:
        A = rng.normal(size=(m, n))
        g = np.asarray(jax.grad(radic_det)(jnp.asarray(A)))
        fd = np.zeros_like(A)
        eps = 1e-6
        for i in range(m):
            for j in range(n):
                E = np.zeros_like(A); E[i, j] = eps
                fd[i, j] = (float(radic_det(jnp.asarray(A + E)))
                            - float(radic_det(jnp.asarray(A - E)))) \\
                    / (2 * eps)
        scale = max(1.0, float(np.max(np.abs(fd))))
        assert np.max(np.abs(g - fd)) <= 1e-5 * scale, (m, n)
    # m == n: one subset, sign +1 -> the classical determinant gradient
    A = rng.normal(size=(4, 4))
    g = np.asarray(jax.grad(radic_det)(jnp.asarray(A)))
    gd = np.asarray(jax.grad(jnp.linalg.det)(jnp.asarray(A)))
    assert np.allclose(g, gd, rtol=1e-10, atol=1e-12)
    # batched VJP vs per-matrix scalar grads, nonuniform cotangents
    As = rng.normal(size=(3, 3, 7))
    cts = np.array([1.0, -2.0, 0.5])
    _, pull = jax.vjp(radic_det_batched, jnp.asarray(As))
    (gb,) = pull(jnp.asarray(cts))
    gb = np.asarray(gb)
    for b in range(3):
        _, ps = jax.vjp(radic_det, jnp.asarray(As[b]))
        (gs,) = ps(jnp.asarray(cts[b]))
        assert np.allclose(gb[b], np.asarray(gs), rtol=1e-9, atol=1e-11), b
    # Pallas backward agrees with the FD-verified jnp backward at kernel
    # (f32) precision, under x64 inputs
    A = rng.normal(size=(3, 8))
    gj = np.asarray(jax.grad(radic_det)(jnp.asarray(A)))
    gp = np.asarray(jax.grad(
        lambda M: radic_det(M, backend="pallas"))(jnp.asarray(A)))
    assert np.allclose(gp, gj, rtol=1e-3, atol=1e-4)
    print("GRAD_F64_OK")
""")


def test_grad_matches_finite_differences_f64():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", F64_GRAD],
                         capture_output=True, text=True, env=env, cwd=REPO)
    assert "GRAD_F64_OK" in out.stdout, (out.stdout, out.stderr[-2000:])


# --------------------------------------------------- f32 in-process checks
def test_grad_square_matches_linalg_det(rng):
    A = jnp.asarray(rng.normal(size=(4, 4)).astype(np.float32))
    g = np.asarray(jax.grad(radic_det)(A))
    gd = np.asarray(jax.grad(jnp.linalg.det)(A))
    np.testing.assert_allclose(g, gd, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("m,n", [(2, 6), (3, 8), (3, 3)])
def test_pallas_grad_matches_jnp(m, n, rng):
    """Scalar and batched Pallas backward vs the jnp backward (which
    the f64 subprocess pins to finite differences)."""
    A = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    gj = np.asarray(jax.grad(radic_det)(A))
    gp = np.asarray(jax.grad(
        lambda M: radic_det(M, backend="pallas"))(A))
    np.testing.assert_allclose(gp, gj, rtol=1e-3, atol=1e-4)
    As = jnp.asarray(rng.normal(size=(4, m, n)).astype(np.float32))
    gj = np.asarray(jax.grad(lambda M: jnp.sum(radic_det_batched(M)))(As))
    gp = np.asarray(jax.grad(
        lambda M: jnp.sum(radic_det_batched(M, backend="pallas")))(As))
    np.testing.assert_allclose(gp, gj, rtol=1e-3, atol=1e-4)


def test_batched_grad_matches_scalar(rng):
    As = jnp.asarray(rng.normal(size=(5, 3, 7)).astype(np.float32))
    cts = jnp.asarray(np.array([1.0, -2.0, 0.5, 3.0, -0.25], np.float32))
    _, pull = jax.vjp(radic_det_batched, As)
    (gb,) = pull(cts)
    gb = np.asarray(gb)
    for b in range(5):
        _, ps = jax.vjp(radic_det, As[b])
        (gs,) = ps(cts[b])
        np.testing.assert_allclose(gb[b], np.asarray(gs),
                                   rtol=1e-5, atol=1e-6)


def test_degenerate_m_gt_n_grad_is_zero(rng):
    """m > n: det ≡ 0 (Definition 3 has no subsets), so the gradient is
    identically zero with the caller's shape — scalar and batched."""
    A = jnp.asarray(rng.normal(size=(4, 2)).astype(np.float32))
    assert float(radic_det(A)) == 0.0
    g = np.asarray(jax.grad(radic_det)(A))
    np.testing.assert_array_equal(g, np.zeros((4, 2), np.float32))
    As = jnp.asarray(rng.normal(size=(3, 4, 2)).astype(np.float32))
    gb = np.asarray(jax.grad(lambda M: jnp.sum(radic_det_batched(M)))(As))
    np.testing.assert_array_equal(gb, np.zeros((3, 4, 2), np.float32))


def test_plan_grad_aot_bit_identical_to_traced(rng):
    """``DetPlan.grad`` (the AOT-lowered serving program) and the traced
    ``jax.vjp`` route lower the same statics into the same program —
    results must match to the bit, including nonuniform cotangents
    (the queue scales ct *inside* the VJP; see module docstring)."""
    m, n, cap = 3, 7, 4
    plan = aot_compile_batched(m, n, cap, chunk=64)
    As = jnp.asarray(rng.normal(size=(cap, m, n)).astype(np.float32))
    cts = jnp.asarray(np.array([1.0, -2.0, 0.5, 0.0], np.float32))
    aot = np.asarray(plan.grad(As, cts))
    _, pull = jax.vjp(lambda M: radic_det_batched(M, chunk=64), As)
    (traced,) = pull(cts)
    np.testing.assert_array_equal(aot, np.asarray(traced))
    # ct = 0 slots (queue padding) are exact zeros, never NaN
    np.testing.assert_array_equal(aot[3], np.zeros((m, n), np.float32))


def test_grad_composes_with_jit_and_plan_cache(rng):
    """Regression for the plan-cache tracer leak: a plan first built
    *inside* an outer ``jax.jit`` trace is cached; its Pascal table must
    be concrete (``ensure_compile_time_eval``), or every later use of
    the cached plan — grad-after-jit, jit-of-grad, plain eager — dies
    with ``UnexpectedTracerError``."""
    default_engine().clear()     # force the build to happen under trace
    A = jnp.asarray(rng.normal(size=(3, 11)).astype(np.float32))

    @jax.jit
    def f(M):
        return radic_det(M) ** 2

    want = float(radic_det(A)) ** 2
    assert abs(float(f(A)) - want) <= 1e-4 * max(1.0, abs(want))
    g1 = np.asarray(jax.grad(radic_det)(A))          # grad after jit
    g2 = np.asarray(jax.jit(jax.grad(radic_det))(A))  # jit of grad
    np.testing.assert_allclose(g1, g2, rtol=1e-6, atol=1e-7)
    assert np.all(np.isfinite(g1))


# ------------------------------------------------------------ serving paths
def test_plan_buckets_never_merge_grad(rng):
    """Grad requests bucket by exact (shape, grad): no column merge (the
    padding columns would change the result *shape* and can NaN the
    pullback), and never share a device batch with value requests."""
    policy = BucketPolicy(max_batch=8, mode="merge", pin_capacity=True)
    reqs = []
    for seq, (shape, grad) in enumerate([((2, 5), False), ((2, 6), False),
                                         ((2, 5), True), ((2, 6), True),
                                         ((2, 5), True)]):
        arr = rng.normal(size=shape).astype(np.float32)
        reqs.append(Request(seq=seq, array=arr, shape=shape, grad=grad))
    plans = plan_buckets(reqs, policy)
    for sp in plans:
        grads = {r.grad for r in sp.requests}
        assert len(grads) == 1          # value and grad never co-batch
        if grads == {True}:
            # exact shape preserved — no canonical column class
            assert {r.shape for r in sp.requests} == {sp.shape}
    # the two value requests merged to one canonical bucket, the three
    # grad requests stayed in two exact-shape buckets
    assert sum(1 for sp in plans if not sp.grad) == 1
    assert sum(1 for sp in plans if sp.grad) == 2


def test_queue_grad_requests(rng):
    """Gradient traffic through the real DetQueue: mixed value/grad
    burst, results equal the traced VJP (cotangent pulled through),
    values untouched by the grad slots sharing the pipeline."""
    policy = BucketPolicy(max_batch=8, mode="merge", pin_capacity=True)
    mats = [rng.normal(size=(3, 7)).astype(np.float32) for _ in range(6)]
    cts = [1.0, -2.0, 0.5, 1.0, 3.0, 0.0]
    with DetQueue(chunk=128, policy=policy) as q:
        futs = q.submit_many(
            mats, [(i % 2 == 0, cts[i]) for i in range(6)])
        got = [f.result(timeout=300) for f in futs]
        fg = q.submit(mats[0], grad=True, cotangent=-1.5)
        gneg = fg.result(timeout=300)
    for i, (A, val) in enumerate(zip(mats, got)):
        Aj = jnp.asarray(A[None])
        if i % 2 == 0:
            _, pull = jax.vjp(lambda M: radic_det_batched(M, chunk=128), Aj)
            (want,) = pull(jnp.asarray([cts[i]], np.float32))
            assert isinstance(val, np.ndarray) and val.shape == (3, 7)
            np.testing.assert_allclose(val, np.asarray(want)[0],
                                       rtol=1e-5, atol=1e-6)
        else:
            want = float(radic_det_batched(Aj, chunk=128)[0])
            assert isinstance(val, float)
            assert abs(val - want) <= 1e-4 * max(1.0, abs(want))
    np.testing.assert_allclose(
        gneg, -1.5 * np.asarray(got[0]), rtol=1e-5, atol=1e-6)


def test_queue_grad_degenerate_and_errors(rng):
    """m > n grad requests resolve to exact zero arrays through the
    queue's trivial path; grads keyword validation mirrors values."""
    with DetQueue(chunk=64) as q:
        f = q.submit(rng.normal(size=(4, 2)).astype(np.float32), grad=True)
        val = f.result(timeout=120)
        np.testing.assert_array_equal(val, np.zeros((4, 2), np.float32))
        with pytest.raises(ValueError):
            q.submit_many([rng.normal(size=(2, 5)).astype(np.float32)],
                          grads=[(True, 1.0), (False, 1.0)])


# ---------------------------------------------------- mesh backend (8 dev)
MESH_GRAD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core import radic_det_batched
    assert len(jax.devices()) == 8
    rng = np.random.default_rng(3)
    As = jnp.asarray(rng.normal(size=(4, 3, 8)).astype(np.float32))
    cts = jnp.asarray(np.array([1.0, -2.0, 0.5, 3.0], np.float32))
    _, pull = jax.vjp(lambda M: radic_det_batched(M, chunk=16), As)
    (want,) = pull(cts)
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    # rank-space sharded over the whole mesh, batch replicated
    _, pm = jax.vjp(lambda M: radic_det_batched(M, mesh=mesh, chunk=16), As)
    (got,) = pm(cts)
    assert np.allclose(np.asarray(got), np.asarray(want),
                       rtol=1e-4, atol=1e-5), "mesh grad drifted"
    # batch sharded over "data", rank-space over "model"
    _, pb = jax.vjp(lambda M: radic_det_batched(
        M, mesh=mesh, batch_axis="data", chunk=16), As)
    (got_b,) = pb(cts)
    assert np.allclose(np.asarray(got_b), np.asarray(want),
                       rtol=1e-4, atol=1e-5), "batch-axis mesh grad drifted"
    print("MESH_GRAD_OK")
""")


def test_mesh_batched_grad_eight_devices():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", MESH_GRAD],
                         capture_output=True, text=True, env=env, cwd=REPO,
                         timeout=600)
    assert "MESH_GRAD_OK" in out.stdout, (out.stdout, out.stderr[-2000:])
