"""Substrate tests: checkpoint/restart, elastic mesh, watchdog/straggler,
data determinism, optimizer, gradient compression."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, Prefetcher, SyntheticLMData
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine
from repro.parallel.compress import (dequantize_int8, init_error_feedback,
                                     psum_int8, quantize_int8,
                                     topk_with_error_feedback)
from repro.runtime import StepTimer, Watchdog, choose_mesh, run_grains


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.zeros((3,)),
            "nested": {"s": jnp.asarray(3)}}
    m.save(7, tree)
    step, out = m.restore(tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keeps_last_and_latest(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.ones((2,))}
    for s in (1, 2, 3):
        m.save(s, jax.tree.map(lambda x: x * s, tree))
    assert m.latest_step() == 3
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000002", "step_00000003"]
    step, out = m.restore(tree, step=2)
    assert float(out["w"][0]) == 2.0


def test_checkpoint_async_then_blocking_same_step(tmp_path):
    m = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.ones((128, 128))}
    m.save_async(5, tree)
    m.save(5, tree)  # must not collide with the in-flight async write
    assert m.latest_step() == 5


def test_checkpoint_crash_atomicity(tmp_path):
    """A leftover tmp dir (simulated crash) never corrupts LATEST."""
    m = CheckpointManager(str(tmp_path))
    m.save(1, {"w": jnp.ones((2,))})
    os.makedirs(os.path.join(str(tmp_path), ".tmp-step_00000002"))
    assert m.latest_step() == 1
    step, _ = m.restore({"w": jnp.ones((2,))})
    assert step == 1


def test_checkpoint_restores_mid_stream_data(tmp_path):
    """Restart consumes the same batches it would have seen (determinism)."""
    data = SyntheticLMData(DataConfig(vocab_size=64, seq_len=8,
                                      global_batch=4))
    run1 = [data.batch(s)["tokens"] for s in range(6)]
    run2 = [data.batch(s)["tokens"] for s in range(3, 6)]  # "resumed" at 3
    for a, b in zip(run1[3:], run2):
        np.testing.assert_array_equal(a, b)


# ------------------------------------------------------------------ elastic
def test_choose_mesh_shrinks_on_failures():
    full = choose_mesh(512, max_model=16, want_pods=2)
    assert full.shape == (2, 16, 16)
    # lose a pod
    half = choose_mesh(256, max_model=16)
    assert half.shape == (16, 16)
    # lose arbitrary nodes: 509 -> largest pow2 = 256
    broken = choose_mesh(509, max_model=16)
    assert broken.n_devices == 256
    tiny = choose_mesh(1)
    assert tiny.shape == (1, 1)


# ------------------------------------------------------ watchdog/stragglers
def test_watchdog_fires_on_stall():
    fired = []
    wd = Watchdog(timeout_s=0.1, on_stall=lambda: fired.append(1)).start()
    time.sleep(0.3)
    wd.stop()
    assert fired


def test_watchdog_quiet_when_beating():
    wd = Watchdog(timeout_s=0.3, on_stall=lambda: None).start()
    for _ in range(5):
        wd.beat()
        time.sleep(0.05)
    wd.stop()
    assert not wd.fired


def test_step_timer_flags_outliers():
    t = StepTimer(warmup=2)
    for i in range(10):
        assert not t.record(i, 1.0)
    assert t.record(10, 5.0)
    assert t.stragglers == [10]


def test_run_grains_survives_failures_and_speculation():
    vals = [float(i) for i in range(8)]
    fns = [lambda v=v: v for v in vals]
    # worker 0 dies on grains 1 and 3; speculation must recover
    out = run_grains(fns, n_workers=3, fail_on={(0, 1), (0, 3), (1, 5)})
    assert out == vals


def test_run_grains_no_duplicates():
    calls = []
    import threading
    lock = threading.Lock()

    def mk(i):
        def f():
            with lock:
                calls.append(i)
            return i
        return f
    out = run_grains([mk(i) for i in range(16)], n_workers=4)
    assert out == list(range(16))


# --------------------------------------------------------------------- data
def test_prefetcher_delivers_in_order():
    data = SyntheticLMData(DataConfig(vocab_size=32, seq_len=4,
                                      global_batch=2))
    pf = Prefetcher(data, start_step=5)
    steps = [pf.next()[0] for _ in range(4)]
    pf.close()
    assert steps == [5, 6, 7, 8]


def test_data_sharding_partitions_batch():
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=8,
                     num_shards=2, shard_id=0)
    d0 = SyntheticLMData(cfg)
    d1 = SyntheticLMData(
        DataConfig(vocab_size=64, seq_len=8, global_batch=8,
                   num_shards=2, shard_id=1))
    b0, b1 = d0.batch(0)["tokens"], d1.batch(0)["tokens"]
    assert b0.shape == (4, 8) and b1.shape == (4, 8)
    assert not np.array_equal(b0, b1)  # different shards, different data


# -------------------------------------------------------------------- optim
def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params, cfg)
    for _ in range(200):
        grads = jax.tree.map(lambda x: 2 * x, params)
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_grad_clip_caps_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0)
    params = {"x": jnp.zeros((4,))}
    state = adamw_init(params, cfg)
    _, _, metrics = adamw_update(params,
                                 {"x": jnp.full((4,), 1e6)}, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # raw norm reported


def test_warmup_cosine_shape():
    f = warmup_cosine(1.0, 10, 100)
    assert float(f(jnp.asarray(0))) == 0.0
    assert abs(float(f(jnp.asarray(10))) - 1.0) < 0.11
    assert float(f(jnp.asarray(100))) <= 0.11


# ----------------------------------------------------------------- compress
def test_int8_quantization_error_bound(rng):
    x = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-7


def test_psum_int8_single_device_identity(rng):
    g = {"w": jnp.asarray(rng.normal(size=(32,)).astype(np.float32))}
    out = psum_int8(g, axis_names=())  # no axes: pure quant round-trip
    err = np.abs(np.asarray(out["w"]) - np.asarray(g["w"])).max()
    assert err < 0.05


def test_topk_error_feedback_accumulates(rng):
    g = {"w": jnp.asarray(rng.normal(size=(100,)).astype(np.float32))}
    mem = init_error_feedback(g)
    total = np.zeros(100, np.float32)
    for _ in range(50):
        sg, mem = topk_with_error_feedback(g, mem, frac=0.05)
        total += np.asarray(sg["w"])
    # error feedback => long-run average ≈ the true gradient direction
    corr = np.corrcoef(total, np.asarray(g["w"]))[0, 1]
    assert corr > 0.99
