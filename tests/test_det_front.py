"""Battery for the multi-worker bucket-routing serving front.

The load-bearing invariant mirrors the DetQueue battery one level up:
per-request results are independent of *which worker* served them and
of any re-routing that happened along the way.  With capacity pinned
and the merge policy fixed, a request's determinant through a 2-worker
``DetFront`` is bit-identical to the single-process ``DetQueue`` — and
stays bit-identical when the owning worker is SIGKILLed mid-flight and
its pending requests re-plan on the survivor (plans are pure functions
of their key).

Worker processes spawn real jax-importing children; the module keeps
the request counts small and shares policies so the battery stays
CI-sized.
"""

import numpy as np
import pytest

from repro.core import comb
from repro.core.engine import stable_key_hash
from repro.launch.det_front import (DetFront, HashRing, WorkerError,
                                    route_key)
from repro.launch.det_queue import (BucketPolicy, DetQueue, LoadShedError,
                                    QueueClosedError)

CHUNK = 128
CAP = 8
# the DetQueue battery's heterogeneous pool, incl. one m > n degenerate
SHAPES = [(1, 4), (2, 5), (2, 6), (3, 7), (3, 9), (4, 10), (4, 2)]

PINNED = BucketPolicy(max_batch=CAP, mode="merge", pin_capacity=True)


def _mats(rng, num):
    out = []
    for _ in range(num):
        m, n = SHAPES[int(rng.integers(0, len(SHAPES)))]
        out.append(rng.normal(size=(m, n)).astype(np.float32))
    return out


def _queue_reference(mats, policy=PINNED):
    """The single-process ground truth for a request set."""
    with DetQueue(chunk=CHUNK, policy=policy) as q:
        dets, _ = q.serve(mats, timeout=300)
    return dets


# --------------------------------------------------------------- pure pieces
def test_stable_key_hash_is_process_stable():
    """The ring hash must not depend on PYTHONHASHSEED — pin a value so
    any accidental fallback to builtin hash() fails loudly."""
    key = (3, 9, 8, "float32", False)
    assert stable_key_hash(key) == stable_key_hash(tuple(key))
    assert stable_key_hash(key) != stable_key_hash((3, 9, 8, "float64",
                                                    False))
    import pathlib
    import subprocess
    import sys
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run(
        [sys.executable, "-c",
         "from repro.core.engine import stable_key_hash;"
         "print(stable_key_hash((3, 9, 8, 'float32', False)))"],
        capture_output=True, text=True,
        env={"PYTHONPATH": src, "PYTHONHASHSEED": "12345"})
    assert out.returncode == 0, out.stderr
    assert int(out.stdout) == stable_key_hash(key)


def test_route_key_projects_policy_canonical_shape():
    merge = BucketPolicy(max_batch=8, mode="merge", col_class=4, col_max=16)
    never = BucketPolicy(max_batch=8, mode="never")
    # merging policies route by canonical shape: everything that could
    # coalesce must share one owner
    assert route_key((2, 5), merge, np.float32, False) \
        == route_key((2, 6), merge, np.float32, False) \
        == (2, 8, 8, "float32", False)
    # exact-shape policies route exact
    assert route_key((2, 5), never, np.float32, False) \
        != route_key((2, 6), never, np.float32, False)
    # dtype and x64 select different program families
    assert route_key((2, 5), never, np.float32, False) \
        != route_key((2, 5), never, np.float64, False)
    assert route_key((2, 5), never, np.float32, False) \
        != route_key((2, 5), never, np.float32, True)


def test_hash_ring_consistency_on_removal():
    """Removing one worker moves only the keys it owned; every other
    key keeps its owner — the consistent-hashing property that makes
    re-routing deterministic and minimal."""
    ring = HashRing([0, 1, 2], vnodes=64)
    keys = [(m, n, 8, "float32", False) for m in range(1, 6)
            for n in range(m, 12)]
    before = {k: ring.owner(k) for k in keys}
    ring.remove(1)
    after = {k: ring.owner(k) for k in keys}
    for k in keys:
        if before[k] != 1:
            assert after[k] == before[k]
        else:
            assert after[k] != 1
    # walk order: first element is the owner, all workers appear once
    ring2 = HashRing([0, 1, 2], vnodes=64)
    for k in keys:
        w = ring2.walk(k)
        assert w[0] == ring2.owner(k) and sorted(w) == [0, 1, 2]


def test_hash_ring_empty_and_validation():
    with pytest.raises(RuntimeError):
        HashRing([]).owner((1, 2, 3))
    with pytest.raises(ValueError):
        HashRing([0], vnodes=0)
    assert HashRing([]).walk((1, 2, 3)) == []


# ------------------------------------------------------------- bit identity
@pytest.mark.parametrize("workers", [1, 2])
def test_front_bit_identical_to_single_queue(workers, rng):
    """The tentpole invariant: the same request set produces identical
    bits through DetQueue (1 process) and DetFront (1 and 2 workers)."""
    mats = _mats(rng, 30)
    want = _queue_reference(mats)
    with DetFront(workers=workers, chunk=CHUNK, policy=PINNED) as front:
        got, stats = front.serve(mats, timeout=300)
        assert front.alive_workers == list(range(workers))
    assert got == want
    assert stats["front"]["submitted"] == 30
    assert stats["total"]["completed"] == 30
    assert stats["front"]["worker_deaths"] == 0


@pytest.mark.parametrize("workers,shm", [(1, False), (2, False), (2, True)])
def test_front_grad_bit_identical_to_single_queue(workers, shm, rng):
    """Gradient traffic extends the tentpole invariant (DESIGN_GRAD.md):
    a mixed value/grad burst with nonuniform cotangents produces
    bit-identical results through DetFront — local and zero-copy shm
    transports — as through the 1-process DetQueue.  Grad results are
    (m, n) arrays; every bit must survive the wire."""
    mats = _mats(rng, 20)
    grads = [(i % 3 == 0, [1.0, -2.0, 0.5, 1.5][i % 4])
             for i in range(len(mats))]
    with DetQueue(chunk=CHUNK, policy=PINNED) as q:
        want = [f.result(timeout=300)
                for f in q.submit_many(mats, grads)]
    with DetFront(workers=workers, chunk=CHUNK, policy=PINNED,
                  shm=shm) as front:
        got = [f.result(timeout=300)
               for f in front.submit_many(mats, grads)]
    for i, (g, w) in enumerate(zip(got, want)):
        if grads[i][0]:
            assert isinstance(g, np.ndarray)
            assert g.shape == mats[i].shape
            np.testing.assert_array_equal(g, w)  # bit identity, no tol
        else:
            assert g == w


def test_front_worker_kill_reroutes_bit_identical(rng):
    """SIGKILL the worker that owns a hot shape while its requests are
    pending: the front must detect the death, re-route the orphans to
    the survivor, and still deliver bit-identical results for every
    request (plans are pure functions of the key)."""
    mats = _mats(rng, 40)
    want = _queue_reference(mats)
    with DetFront(workers=2, chunk=CHUNK, policy=PINNED) as front:
        victim = front.owner_of((3, 9))
        futs = front.submit_many(mats)
        front.kill_worker(victim)
        got = [f.result(timeout=300) for f in futs]
        stats = front.snapshot()
        assert front.alive_workers == [1 - victim]
    assert got == want
    assert stats["front"]["worker_deaths"] == 1
    # the kill landed before the first result could possibly complete
    # (cold compile takes far longer than the submit->kill window), so
    # the victim's routed share was actually re-routed
    assert stats["front"]["rerouted"] > 0
    # the front delivered every request exactly once (the dead worker's
    # own counters died with it; the front's view is authoritative)
    assert stats["front"]["completed"] == 40


def test_front_retire_worker_drains_and_requeues(rng):
    """The graceful-downscale path: retire_worker hands the un-staged
    backlog back via DetQueue.drain_pending, the ring drops the worker,
    and everything still resolves bit-identically on the survivor."""
    mats = [rng.normal(size=(3, 7)).astype(np.float32) for _ in range(16)]
    want = _queue_reference(mats)
    # linger keeps the worker's backlog un-staged long enough for the
    # retire to deterministically catch requests in drain_pending
    with DetFront(workers=2, chunk=CHUNK, policy=PINNED,
                  linger_s=3.0) as front:
        victim = front.owner_of((3, 7))
        futs = front.submit_many(mats)
        front.retire_worker(victim)
        got = [f.result(timeout=300) for f in futs]
        stats = front.snapshot()
        assert front.alive_workers == [1 - victim]
    assert got == want
    assert stats["front"]["worker_deaths"] == 0  # clean exit, not a death
    assert stats["front"]["rerouted"] > 0


def test_front_all_workers_dead_fails_pending(rng):
    mats = [rng.normal(size=(3, 9)).astype(np.float32) for _ in range(8)]
    front = DetFront(workers=1, chunk=CHUNK, policy=PINNED)
    try:
        futs = front.submit_many(mats)
        front.kill_worker(0)
        for f in futs:
            with pytest.raises(RuntimeError):
                f.result(timeout=120)
        with pytest.raises(RuntimeError):
            front.submit(mats[0])
    finally:
        front.close()


# ------------------------------------------------ ownership and balance
def test_plan_ownership_is_exclusive_and_sticky(rng):
    """Every shape's plan family lives on exactly one worker: the
    aggregated pool plan-cache misses equal the number of distinct
    program families — no duplicated XLA compiles across the pool."""
    shapes = [(2, 5), (3, 7), (3, 9), (4, 10)]
    mats = [rng.normal(size=shapes[i % 4]).astype(np.float32)
            for i in range(32)]
    with DetFront(workers=2, chunk=CHUNK, policy=PINNED) as front:
        owners = {s: front.owner_of(s) for s in shapes}
        front.serve(mats, timeout=300)
        stats = front.snapshot()
        assert {s: front.owner_of(s) for s in shapes} == owners  # sticky
    # merge policy canonicalizes (2,5)->(2,8), (3,7)/(3,9)->(3,12)...
    families = {route_key(s, PINNED, np.float32, False) for s in shapes}
    assert stats["total"]["plan_cache"]["misses"] == len(families)
    per_worker_sizes = [snap["plan_cache"]["size"]
                        for snap in stats["workers"].values()]
    assert sum(per_worker_sizes) == len(families)


def test_bounded_load_placement_splits_equal_families():
    """With K equal-weight plan families and N workers, bounded-load
    placement may not park more than (1 + eps) * K/N weight on any one
    worker — the raw-arc split that motivated it routinely does."""
    with DetFront(workers=2, chunk=CHUNK,
                  policy=BucketPolicy(max_batch=CAP, mode="never")) as front:
        shapes = [(3, n) for n in range(8, 24)]  # 16 families
        for s in shapes:
            front.owner_of(s)
        loads = front.snapshot(timeout=60)["front"]["plan_load"]
    total = sum(loads.values())
    assert len(loads) == 2 and total > 0
    assert max(loads.values()) <= total * (1 + front._balance_eps) / 2 \
        + max(comb(n, 3) for _, n in shapes)


# ------------------------------------------------------ queue-surface parity
def test_front_loadshed_propagates_end_to_end(rng):
    """Per-worker admission control must surface as LoadShedError on the
    front's futures AND its poll stream, exactly once per request."""
    A = rng.normal(size=(2, 5)).astype(np.float32)
    with DetFront(workers=2, chunk=CHUNK, max_pending=2,
                  policy=BucketPolicy(max_batch=CAP,
                                      pin_capacity=True)) as front:
        futs = front.submit_many([A] * 10)  # one shape -> one worker
        excs = [f.exception(timeout=120) for f in futs]
        served = [f for f, e in zip(futs, excs) if e is None]
        shed = [f for f, e in zip(futs, excs)
                if isinstance(e, LoadShedError)]
        assert len(served) == 2 and len(shed) == 8
        by_seq = {}
        while len(by_seq) < 10:
            got = front.poll(timeout=60.0)
            assert got, "poll timed out with responses outstanding"
            by_seq.update(got)
        stats = front.snapshot()
    assert set(by_seq) == {f.seq for f in futs}
    assert sum(isinstance(v, LoadShedError) for v in by_seq.values()) == 8
    assert stats["front"]["shed"] == 8 and stats["total"]["shed"] == 8


def test_front_error_propagates_with_type(rng):
    """A worker-side plan-time failure (C(40,16) overflowing int32)
    surfaces as the same exception type on the front future; the pool
    keeps serving other requests."""
    with DetFront(workers=2, chunk=CHUNK, policy=PINNED) as front:
        bad = front.submit(np.ones((16, 40), np.float32))
        with pytest.raises(OverflowError):
            bad.result(timeout=300)
        ok = front.submit(np.ones((4, 2), np.float32))  # m > n => 0
        assert ok.result(timeout=300) == 0.0
        stats = front.snapshot()
    assert stats["front"]["errors"] == 1


def test_worker_error_rebuild_fallback():
    from repro.launch.det_front import _rebuild_exc
    assert isinstance(_rebuild_exc("OverflowError", "x"), OverflowError)
    assert isinstance(_rebuild_exc("LoadShedError", "x"), LoadShedError)
    exc = _rebuild_exc("SomeExoticError", "boom")
    assert isinstance(exc, WorkerError) and "SomeExoticError" in str(exc)


def test_front_poll_stream_exactly_once(rng):
    mats = _mats(rng, 20)
    with DetFront(workers=2, chunk=CHUNK, policy=PINNED) as front:
        futs = front.submit_many(mats)
        by_seq = {}
        while len(by_seq) < len(mats):
            got = front.poll(timeout=60.0)
            assert got, "poll timed out with responses outstanding"
            by_seq.update(got)
    assert by_seq == {f.seq: f.result() for f in futs}


def test_front_close_idempotent_and_rejects_submits(rng):
    front = DetFront(workers=1, chunk=CHUNK, policy=PINNED)
    fut = front.submit(rng.normal(size=(2, 5)).astype(np.float32))
    front.close()
    assert fut.done()  # close drains accepted work before stopping
    with pytest.raises(QueueClosedError):
        front.submit(np.ones((2, 5), np.float32))
    front.close()  # idempotent
    # the request's response is still pollable after close, then the
    # stream ends cleanly (no hang) even with timeout=None semantics
    assert front.poll(timeout=0.0) == [(fut.seq, fut.result())]
    assert front.poll(timeout=0.0) == []


def test_front_validation():
    with pytest.raises(ValueError):
        DetFront(workers=0)
    with pytest.raises(ValueError):
        DetFront(workers=1, max_batch=8,
                 policy=BucketPolicy(max_batch=64))


def test_front_stats_aggregation_shape(rng):
    mats = _mats(rng, 12)
    with DetFront(workers=2, chunk=CHUNK, policy=PINNED) as front:
        front.serve(mats, timeout=300)
        stats = front.snapshot()
    f, tot, per = stats["front"], stats["total"], stats["workers"]
    assert f["submitted"] == 12 and sum(f["routed"].values()) == 12
    assert tot["submitted"] == tot["completed"] == 12
    assert set(per) <= {0, 1} and len(per) == f["workers_alive"] == 2
    assert tot["backlog_peak"] == max(s["backlog_peak"]
                                      for s in per.values())
    for key in ("hits", "misses", "evictions", "size",
                "store_hits", "store_misses"):
        assert tot["plan_cache"][key] == sum(s["plan_cache"][key]
                                             for s in per.values())
    # no store configured: the store counters stay zero
    assert tot["plan_cache"]["store_hits"] == 0
    assert f["prefill"] is False and f["cold_workers"] == []
    # bucket merge across workers preserves counts
    assert sum(b["count"] for b in tot["buckets"].values()) == 12


# ------------------------------------------------------------ warm start
def test_front_warm_start_from_plan_store_bit_identical(rng, tmp_path):
    """The PR's end-to-end invariant (DESIGN_PERSIST.md): a front over a
    populated plan store restores plans instead of compiling (store hits
    in the aggregated snapshot) and every result stays bit-identical to
    the cold 1-process DetQueue."""
    mats = _mats(rng, 20)
    want = _queue_reference(mats)  # cold reference, no store anywhere
    store = str(tmp_path / "plans")
    # populate the store: one cold pass through a persistent DetQueue
    with DetQueue(chunk=CHUNK, policy=PINNED, persist_dir=store) as q:
        q.serve(mats, timeout=300)
    with DetFront(workers=1, chunk=CHUNK, policy=PINNED,
                  persist_dir=store) as front:
        got, stats = front.serve(mats, timeout=300)
    assert got == want
    pc = stats["total"]["plan_cache"]
    assert pc["store_hits"] >= 1        # the worker arrived warm
    assert stats["front"]["prefill"] is True  # auto-on with a store


def test_front_join_with_prefill_warms_before_admission(rng, tmp_path):
    """A worker joining via the accept listener with a populated plan
    store is shipped the front's live plan families in the handshake and
    warms them (store first) before it is admitted: its very first
    snapshot shows store hits, and results match the cold join exactly."""
    import threading
    from repro.launch.transport import run_worker_client
    mats = _mats(rng, 24)
    want = _queue_reference(mats)
    store = str(tmp_path / "plans")
    with DetQueue(chunk=CHUNK, policy=PINNED, persist_dir=store) as q:
        q.serve(mats, timeout=300)
    with DetFront(workers=1, chunk=CHUNK, policy=PINNED,
                  persist_dir=store, accept="127.0.0.1:0") as front:
        first = [f.result(timeout=300)
                 for f in front.submit_many(mats[:12])]
        assert front._prefill_entries()  # live families to ship
        joiner = threading.Thread(
            target=run_worker_client, args=(front.accept_address,),
            kwargs={"log": lambda *a, **k: None}, daemon=True)
        joiner.start()
        deadline = 60.0
        import time
        t0 = time.monotonic()
        while len(front.alive_workers) != 2:
            assert time.monotonic() - t0 < deadline
            time.sleep(0.05)
        snap = front.snapshot()
        joiner_wid = [w for w in front.alive_workers if w != 0][0]
        jpc = snap["workers"][joiner_wid]["plan_cache"]
        # admitted already warm: the prefill consulted the store before
        # the worker answered ready
        assert jpc["store_hits"] >= 1
        assert jpc["size"] >= 1
        rest = [f.result(timeout=300)
                for f in front.submit_many(mats[12:])]
    joiner.join(timeout=30)
    assert first + rest == want
