"""Fixture battery for the reprolint suite (tools/lint).

Each pass gets at least one known-clean and one known-violating snippet
(written to a tmp tree shaped like the real one, since several passes
scope by path), plus suppression honoring, JSON output shape, the
exit-code contract, and the acceptance sweep over the shipped tree.

The linter is stdlib-only and lives outside ``src``, so these tests
import it by repo root rather than through ``PYTHONPATH=src``.
"""

import json
import pathlib
import subprocess
import sys

import pytest

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT))

from tools.lint import ALL_PASSES, lint_paths, pass_ids  # noqa: E402
from tools.lint.core import main as lint_main  # noqa: E402


def run_lint(tree: dict[str, str], tmp_path, select: str | None = None):
    """Write ``tree`` (relpath -> source) under tmp_path and lint it."""
    for rel, src in tree.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(src)
    passes = ALL_PASSES if select is None else \
        [p for p in ALL_PASSES if p.id == select]
    findings, n = lint_paths([str(tmp_path)], passes)
    return findings


def ids(findings):
    return sorted({f.pass_id for f in findings})


# ------------------------------------------------------------- compat-seam
CLEAN_COMPAT = """
from repro.parallel.compat import shard_map, psum_scalar

def f(mesh):
    return shard_map(lambda x: x, mesh=mesh, in_specs=(), out_specs=())
"""

ALIASED_IMPORT = """
import jax.experimental.shard_map as sm

def f():
    return sm.shard_map
"""


def test_compat_seam_clean(tmp_path):
    fs = run_lint({"src/repro/parallel/ops.py": CLEAN_COMPAT}, tmp_path,
                  "compat-seam")
    assert fs == []


def test_compat_seam_aliased_import_fires(tmp_path):
    fs = run_lint({"src/repro/parallel/ops.py": ALIASED_IMPORT}, tmp_path,
                  "compat-seam")
    assert fs and all(f.pass_id == "compat-seam" for f in fs)
    assert any("jax.experimental.shard_map" in f.message for f in fs)


@pytest.mark.parametrize("snippet", [
    "from jax.experimental import shard_map\n",
    "from jax import shard_map as smap\n",
    "import jax as j\n\ndef f():\n    return j.shard_map\n",
    "import jax\n\ndef f():\n    return jax.experimental.shard_map"
    ".shard_map\n",
    "import jax\n\ndef f():\n    return getattr(jax, 'shard_map')\n",
])
def test_compat_seam_spellings_fire(tmp_path, snippet):
    fs = run_lint({"src/repro/parallel/ops.py": snippet}, tmp_path,
                  "compat-seam")
    assert fs, snippet


def test_compat_seam_exempts_compat_py(tmp_path):
    fs = run_lint({"src/repro/parallel/compat.py": ALIASED_IMPORT},
                  tmp_path, "compat-seam")
    assert fs == []


def test_compat_seam_ignores_strings_and_docstrings(tmp_path):
    src = '"""mentions jax.experimental.shard_map in prose."""\n' \
          'NAME = "jax.shard_map"\n'
    fs = run_lint({"src/repro/parallel/ops.py": src}, tmp_path,
                  "compat-seam")
    assert fs == []


# --------------------------------------------------------- lock-discipline
CLEAN_LOCKED = """
import threading

class Q:
    _GUARDED_BY = {"_pending": "_lock", "_resp": ("_cv",)}

    def __init__(self):
        self._lock = threading.Lock()
        self._cv = threading.Condition()
        self._pending = []
        self._resp = []

    def push(self, x):
        with self._lock:
            self._pending.append(x)
        with self._cv:
            self._resp.append(x)
"""

OFF_LOCK_WRITE = """
import threading

class Q:
    _GUARDED_BY = {"_pending": "_lock"}

    def __init__(self):
        self._lock = threading.Lock()
        self._pending = []

    def push(self, x):
        self._pending.append(x)

    def peek(self):
        with self._wrong_lock:
            return len(self._pending)
"""


def test_lock_discipline_clean(tmp_path):
    fs = run_lint({"src/repro/launch/q.py": CLEAN_LOCKED}, tmp_path,
                  "lock-discipline")
    assert fs == []


def test_lock_discipline_off_lock_access_fires(tmp_path):
    fs = run_lint({"src/repro/launch/q.py": OFF_LOCK_WRITE}, tmp_path,
                  "lock-discipline")
    assert len(fs) == 2  # bare access + access under the wrong lock
    assert all("_pending" in f.message for f in fs)


def test_lock_discipline_init_exempt_and_condition_alias(tmp_path):
    src = CLEAN_LOCKED.replace(
        '("_cv",)', '("_lock", "_cv")')  # either lock acceptable
    fs = run_lint({"src/repro/launch/q.py": src}, tmp_path,
                  "lock-discipline")
    assert fs == []


def test_lock_discipline_unregistered_class_ignored(tmp_path):
    src = "class P:\n    def f(self):\n        self._pending = 1\n"
    fs = run_lint({"src/repro/launch/p.py": src}, tmp_path,
                  "lock-discipline")
    assert fs == []


# ------------------------------------------------------------- wire-safety
CLEAN_WIRE = """
def report(link, seq, fut, q, wid):
    link.send(("result", seq, float(fut.result())))
    link.send(("stats", wid, q.snapshot(), {"n": int(seq)}))
"""

NUMPY_IN_WIRE = """
import numpy as np

def report(link, seq, total):
    link.send(("stats", seq, {"total": np.int64(total)}))
"""

CLOSURE_IN_WIRE = """
def report(link, seq):
    def cb(x):
        return x
    link.send(("result", seq, cb))
    link.send(("result", seq, lambda x: x))
"""


def test_wire_safety_clean(tmp_path):
    fs = run_lint({"src/repro/launch/w.py": CLEAN_WIRE}, tmp_path,
                  "wire-safety")
    assert fs == []


def test_wire_safety_numpy_scalar_in_dict_fires(tmp_path):
    fs = run_lint({"src/repro/launch/w.py": NUMPY_IN_WIRE}, tmp_path,
                  "wire-safety")
    assert len(fs) == 1
    assert "numpy.int64" in fs[0].message


def test_wire_safety_closures_fire(tmp_path):
    fs = run_lint({"src/repro/launch/w.py": CLOSURE_IN_WIRE}, tmp_path,
                  "wire-safety")
    assert len(fs) == 2
    assert any("lambda" in f.message for f in fs)
    assert any("function object 'cb'" in f.message for f in fs)


def test_wire_safety_unvetted_call_fires(tmp_path):
    src = "def f(link, x):\n    link.send((\"r\", make_payload(x)))\n"
    fs = run_lint({"src/repro/launch/w.py": src}, tmp_path, "wire-safety")
    assert len(fs) == 1 and "unvetted call" in fs[0].message


def test_wire_safety_registered_namedtuple_ok(tmp_path):
    src = "def f(link, m, n):\n" \
          "    link.send((\"plan\", PlanKey(int(m), int(n))))\n"
    fs = run_lint({"src/repro/launch/w.py": src}, tmp_path, "wire-safety")
    assert fs == []


SHM_DESC_CLEAN = """
def stage(link, ring, seq, arr):
    desc = shm_descriptor(int(ring.tail), 0, arr.shape, arr.dtype)
    link.send(("batch", seq, [(seq, desc)]))
"""

SHM_DESC_NUMPY = """
import numpy as np

def stage(off, release, arr):
    return shm_descriptor(np.int64(off), release, arr.shape, arr.dtype)
"""

SHM_DESC_LAMBDA = """
def stage(off, release, arr):
    return shm_descriptor(off, release, lambda: arr.shape, arr.dtype)
"""


def test_wire_safety_shm_descriptor_clean(tmp_path):
    """Descriptor builders are vetted producers: a build site with
    plain/opaque args passes, whether or not it sits inside a send."""
    fs = run_lint({"src/repro/launch/w.py": SHM_DESC_CLEAN}, tmp_path,
                  "wire-safety")
    assert fs == []


def test_wire_safety_shm_descriptor_vets_outside_sends(tmp_path):
    """The descriptor's result crosses the wire verbatim, so its build
    site is checked even when the send happens elsewhere — a numpy
    scalar built into a descriptor fires exactly like one built into a
    message."""
    fs = run_lint({"src/repro/launch/w.py": SHM_DESC_NUMPY}, tmp_path,
                  "wire-safety")
    assert len(fs) == 1
    assert "numpy.int64" in fs[0].message


def test_wire_safety_shm_descriptor_closure_fires(tmp_path):
    fs = run_lint({"src/repro/launch/w.py": SHM_DESC_LAMBDA}, tmp_path,
                  "wire-safety")
    assert len(fs) == 1
    assert "lambda" in fs[0].message


# ---------------------------------------------------------- tracer-hygiene
CLEAN_TRACED = """
import functools
import jax
import jax.numpy as jnp

@functools.partial(jax.jit, static_argnames=("flag",))
def f(x, table=None, *, flag=False):
    if table is None:       # trace-time: tracers are never None
        table = jnp.ones(3)
    if flag:                # static arg
        x = x + 1
    if x.shape[0] > 2:      # shapes are static
        x = x * 2
    return jnp.where(x > 0, x, 0.0)
"""

BRANCH_ON_TRACER = """
import jax

@jax.jit
def f(x):
    if x > 0:
        return x
    return -x
"""

HOST_ESCAPES = """
import jax
import numpy as np

@jax.jit
def f(x):
    assert x >= 0
    y = float(x)
    z = x.item()
    w = np.log(x)
    return y + z + w
"""

PALLAS_KERNEL = """
import functools
import jax.experimental.pallas as pl

def kernel(n, x_ref, o_ref):
    if x_ref:
        o_ref[...] = x_ref[...]

def call(x, n):
    return pl.pallas_call(functools.partial(kernel, n),
                          out_shape=None)(x)
"""


def test_tracer_hygiene_clean(tmp_path):
    fs = run_lint({"src/repro/kernels/k.py": CLEAN_TRACED}, tmp_path,
                  "tracer-hygiene")
    assert fs == []


def test_tracer_hygiene_branch_fires(tmp_path):
    fs = run_lint({"src/repro/kernels/k.py": BRANCH_ON_TRACER}, tmp_path,
                  "tracer-hygiene")
    assert len(fs) == 1 and "'if' on traced value 'x'" in fs[0].message


def test_tracer_hygiene_host_escapes_fire(tmp_path):
    fs = run_lint({"src/repro/kernels/k.py": HOST_ESCAPES}, tmp_path,
                  "tracer-hygiene")
    msgs = " | ".join(f.message for f in fs)
    assert "'assert'" in msgs
    assert "float()" in msgs
    assert ".item()" in msgs
    assert "numpy.log" in msgs
    assert len(fs) == 4


def test_tracer_hygiene_pallas_kernel_body(tmp_path):
    fs = run_lint({"src/repro/kernels/k.py": PALLAS_KERNEL}, tmp_path,
                  "tracer-hygiene")
    # partial-bound leading arg n is static; x_ref is traced
    assert len(fs) == 1 and "x_ref" in fs[0].message


# ---------------------------------------------------------- overflow-guard
GUARDED = """
from repro.core.engine import validate_rank_space
from repro.core.pascal import binom_table

def plan(m, n):
    validate_rank_space(m, n, backend="pallas")
    return binom_table(n, m)
"""

UNGUARDED = """
from repro.core.pascal import binom_table

def plan(m, n):
    return binom_table(n, m)
"""


def test_overflow_guard_clean(tmp_path):
    fs = run_lint({"src/repro/kernels/p.py": GUARDED}, tmp_path,
                  "overflow-guard")
    assert fs == []


def test_overflow_guard_fires(tmp_path):
    fs = run_lint({"src/repro/kernels/p.py": UNGUARDED}, tmp_path,
                  "overflow-guard")
    assert len(fs) == 1 and "binom_table" in fs[0].message


def test_overflow_guard_engine_exempt(tmp_path):
    fs = run_lint({"src/repro/core/engine.py": UNGUARDED}, tmp_path,
                  "overflow-guard")
    assert fs == []


def test_overflow_guard_enclosing_scope_guard_ok(tmp_path):
    src = ("from repro.core.engine import validate_rank_space\n"
           "from repro.core.pascal import binom_table\n\n"
           "def make(m, n):\n"
           "    validate_rank_space(m, n, backend='jnp')\n"
           "    def build():\n"
           "        return binom_table(n, m)\n"
           "    return build\n")
    fs = run_lint({"src/repro/kernels/p.py": src}, tmp_path,
                  "overflow-guard")
    assert fs == []


# ------------------------------------------------------------ suppressions
def test_line_suppression_honored(tmp_path):
    src = UNGUARDED.replace(
        "return binom_table(n, m)",
        "return binom_table(n, m)  # reprolint: disable=overflow-guard")
    fs = run_lint({"src/repro/kernels/p.py": src}, tmp_path)
    assert fs == []


def test_suppression_is_per_pass(tmp_path):
    src = UNGUARDED.replace(
        "return binom_table(n, m)",
        "return binom_table(n, m)  # reprolint: disable=wire-safety")
    fs = run_lint({"src/repro/kernels/p.py": src}, tmp_path)
    assert ids(fs) == ["overflow-guard"]  # wrong pass id: still fires


def test_def_level_suppression_covers_body(tmp_path):
    src = ("from repro.core.pascal import binom_table\n\n"
           "def plan(m, n):  # reprolint: disable=overflow-guard\n"
           "    t = binom_table(n, m)\n"
           "    return binom_table(m, n)\n")
    fs = run_lint({"src/repro/kernels/p.py": src}, tmp_path)
    assert fs == []


def test_file_level_suppression(tmp_path):
    src = "# reprolint: disable-file=overflow-guard\n" + UNGUARDED
    fs = run_lint({"src/repro/kernels/p.py": src}, tmp_path)
    assert fs == []


# ------------------------------------------------- CLI, JSON, exit codes
def test_cli_exit_codes_and_json(tmp_path, capsys):
    bad = tmp_path / "src" / "repro" / "kernels"
    bad.mkdir(parents=True)
    (bad / "p.py").write_text(UNGUARDED)

    rc = lint_main([str(tmp_path), "--json"])
    out = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert out["version"] == 1
    assert out["files_scanned"] == 1
    assert out["counts"] == {"overflow-guard": 1}
    (f,) = out["findings"]
    assert set(f) == {"path", "line", "col", "pass", "message"}
    assert f["pass"] == "overflow-guard" and f["line"] == 5

    (bad / "p.py").write_text(GUARDED)
    assert lint_main([str(tmp_path)]) == 0
    assert "clean" in capsys.readouterr().out

    assert lint_main([str(tmp_path / "nope.py")]) == 2
    assert "no such file" in capsys.readouterr().err

    (bad / "p.py").write_text("def broken(:\n")
    assert lint_main([str(tmp_path)]) == 2
    assert "syntax error" in capsys.readouterr().err

    assert lint_main([str(tmp_path), "--select", "bogus-pass"]) == 2


def test_select_restricts_passes(tmp_path, capsys):
    p = tmp_path / "src" / "repro" / "kernels" / "p.py"
    p.parent.mkdir(parents=True)
    p.write_text(UNGUARDED)
    assert lint_main([str(tmp_path), "--select", "compat-seam"]) == 0
    capsys.readouterr()


def test_module_entry_point_runs_without_jax(tmp_path):
    """`python -m tools.lint` must work on a bare interpreter: jax (and
    numpy) must never be imported by the linter itself."""
    tree = tmp_path / "clean.py"
    tree.write_text("X = 1\n")
    probe = ("import sys; sys.modules['jax'] = None; "
             "sys.modules['numpy'] = None; "
             "from tools.lint import main; "
             f"raise SystemExit(main([{str(tree)!r}]))")
    res = subprocess.run([sys.executable, "-c", probe], cwd=REPO_ROOT,
                         capture_output=True, text=True, timeout=60)
    assert res.returncode == 0, res.stderr


# ------------------------------------------------------------- acceptance
def test_shipped_tree_is_clean():
    """Acceptance criterion: the linter exits 0 on the shipped tree."""
    findings, n_files = lint_paths([str(REPO_ROOT / "src" / "repro"),
                                    str(REPO_ROOT / "tools")], ALL_PASSES)
    assert n_files > 50
    assert findings == [], "\n".join(f.render() for f in findings)


def test_pass_catalog_stable():
    assert pass_ids() == ["compat-seam", "lock-discipline", "wire-safety",
                          "tracer-hygiene", "overflow-guard"]
