"""Property-based tests for the front's routing layer: HashRing,
PlanPlacer (bounded-load placement) and the wire-stability of routing
keys.

These are the pure pieces the fault battery leans on — if placement
were not a pure function of (key, membership), "deterministic
re-route" would be vacuous.  Also covers the shm ring's pure protocol
(descriptor round-trips, FIFO allocation invariants) that ShmTransport
builds on.  Runs under hypothesis when installed, otherwise under the
seeded fallback sampler (tests/_hyp_fallback.py), so tier-1 exercises
the same properties on bare boxes.
"""

import math
import pickle

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:
    from _hyp_fallback import given, settings, st

from repro.core.engine import stable_key_hash
from repro.launch.det_front import HashRing, PlanPlacer, route_key
from repro.launch.det_queue import BucketPolicy
from repro.launch.transport import (FrameDecoder, ShmRing, ShmRingReader,
                                    encode_frame, is_shm_descriptor)

# modest shapes keep C(n, m) well away from float trouble while still
# spanning ~6 orders of magnitude of plan weight
_shapes = st.tuples(st.integers(1, 8), st.integers(1, 24))
_shape_lists = st.lists(_shapes, min_size=1, max_size=24)
_worker_counts = st.integers(1, 6)


def _key(shape, max_batch=8):
    m, n = shape
    return (m, n, max_batch, "float32", False)


# ------------------------------------------------------------ bounded load
@settings(max_examples=50)
@given(_shape_lists, _worker_counts)
def test_bounded_load_invariant_arbitrary_weight_mixes(shapes, workers):
    """For ANY mix of C(n, m) plan weights, no worker's accumulated
    load may exceed the bounded-load bound: (1 + eps) x fair share of
    the total, plus one key's weight (the key that tipped it — placement
    is online, a key is never split)."""
    placer = PlanPlacer(list(range(workers)))
    keys = [_key(s) for s in shapes]
    for k in keys:
        placer.assign(k)
    total = sum(placer.key_weight(k) for k in set(keys))
    assert sum(placer.load.values()) == total
    if total == 0:
        return
    bound = total * (1.0 + placer.eps) / workers \
        + max(placer.key_weight(k) for k in set(keys))
    assert max(placer.load.values()) <= bound + 1e-9


@settings(max_examples=50)
@given(_shape_lists, _worker_counts)
def test_placement_is_sticky_and_deterministic(shapes, workers):
    """Re-assigning the same keys changes nothing (sticky), and an
    independent placer over the same worker ids reproduces the same
    ownership map exactly (pure function of key + membership) — the
    property that lets the fault battery predict a victim before the
    front exists."""
    a = PlanPlacer(list(range(workers)))
    b = PlanPlacer(list(range(workers)))
    keys = [_key(s) for s in shapes]
    first = {k: a.assign(k) for k in keys}
    again = {k: a.assign(k) for k in keys}
    other = {k: b.assign(k) for k in keys}
    assert first == again == other


# ------------------------------------------------- monotone consistency
@settings(max_examples=50)
@given(_shape_lists, st.integers(2, 6))
def test_ring_removal_moves_only_the_victims_keys(shapes, workers):
    ring = HashRing(list(range(workers)), vnodes=32)
    keys = {_key(s) for s in shapes}
    before = {k: ring.owner(k) for k in keys}
    victim = ring.owner(_key(sorted(shapes)[0]))
    ring.remove(victim)
    for k in keys:
        if before[k] != victim:
            assert ring.owner(k) == before[k]
        else:
            assert ring.owner(k) != victim


@settings(max_examples=50)
@given(_shape_lists, st.integers(1, 5))
def test_ring_addition_steals_keys_only_for_the_new_node(shapes, workers):
    """Monotone consistency under scale-up: adding a worker may claim
    keys for itself, but must never shuffle a key between two old
    workers."""
    ring = HashRing(list(range(workers)), vnodes=32)
    keys = {_key(s) for s in shapes}
    before = {k: ring.owner(k) for k in keys}
    new = workers  # fresh id
    ring.add(new)
    for k in keys:
        after = ring.owner(k)
        assert after == before[k] or after == new


@settings(max_examples=25)
@given(_shape_lists, st.integers(1, 5))
def test_placer_addition_never_moves_assigned_families(shapes, workers):
    """The property the live-join path leans on (DESIGN_FRONT.md,
    "Dynamic membership"): ``PlanPlacer.add`` extends the ring's
    monotone consistency through the sticky owner map — every family
    assigned before the join keeps its owner afterwards, bit-for-bit,
    and the joiner can only win families it is later *offered*.  Also
    pins idempotence: re-adding a live worker must not zero its load."""
    placer = PlanPlacer(list(range(workers)))
    keys = [_key(s) for s in shapes]
    before = {k: placer.assign(k) for k in keys}
    load_before = dict(placer.load)
    new = workers  # fresh id
    placer.add(new)
    assert {k: placer.assign(k) for k in keys} == before
    assert placer.load[new] == 0.0  # nothing moved to the joiner
    placer.add(0)  # idempotent: live worker keeps its accumulated load
    assert placer.load[0] == load_before[0]


@settings(max_examples=25)
@given(_shape_lists, st.integers(2, 5))
def test_ring_walk_is_a_permutation_starting_at_owner(shapes, workers):
    ring = HashRing(list(range(workers)), vnodes=32)
    for s in shapes:
        w = ring.walk(_key(s))
        assert w[0] == ring.owner(_key(s))
        assert sorted(w) == list(range(workers))


# ----------------------------------------------------- wire round-trips
@settings(max_examples=50)
@given(_shapes, st.integers(1, 64))
def test_stable_key_hash_round_trips_through_wire_encoding(shape, cap):
    """A routing key must hash identically before and after a frame
    encode/decode — including when its components arrive as numpy
    scalars (an array's ``.shape`` member, a decoded payload)."""
    key = (shape[0], shape[1], cap, "float32", False)
    decoded = FrameDecoder().feed(encode_frame(("route", key)))[0][1]
    assert tuple(decoded) == key
    assert stable_key_hash(decoded) == stable_key_hash(key)
    npkey = (np.int64(shape[0]), np.int64(shape[1]), np.int32(cap),
             np.str_("float32"), np.bool_(False))
    assert stable_key_hash(npkey) == stable_key_hash(key)


@settings(max_examples=50)
@given(_shapes)
def test_route_key_canonicalization_shares_owner_for_mergeable_shapes(shape):
    """Under a merging policy, every exact shape that can coalesce into
    a canonical bucket must produce the *same* routing key as the
    canonical shape itself — otherwise one merged program would compile
    on two workers."""
    policy = BucketPolicy(max_batch=8, mode="merge", col_class=4,
                          col_max=16)
    m, n = shape
    canon = policy.canonical_shape(m, n)
    assert route_key(shape, policy, np.float32, False) \
        == route_key(canon, policy, np.float32, False)
    # exact policies route exact
    never = BucketPolicy(max_batch=8, mode="never")
    assert route_key(shape, never, np.float32, False)[:2] == (m, n)


@settings(max_examples=25)
@given(st.lists(st.integers(0, 255), min_size=1, max_size=64))
def test_frame_decoder_survives_arbitrary_chunking(cuts):
    """TCP may deliver any byte split: feeding a frame stream one
    arbitrarily-sized chunk at a time must reproduce the messages
    exactly and in order."""
    msgs = [("result", 7, 3.25), ("hb", 0),
            ("batch", 3, [(1, np.arange(6, dtype=np.float32))]),
            ("stats", 1, {"completed": 2, "buckets": {(2, 5): {"n": 1}}},
             4)]
    blob = b"".join(encode_frame(m) for m in msgs)
    dec = FrameDecoder()
    out = []
    i = 0
    for c in cuts:
        if i >= len(blob):
            break
        step = 1 + (c % 97)
        out.extend(dec.feed(blob[i:i + step]))
        i += step
    out.extend(dec.feed(blob[i:]))
    assert len(out) == len(msgs)
    for got, want in zip(out, msgs):
        if got[0] == "batch":
            assert got[1] == want[1]
            assert np.array_equal(got[2][0][1], want[2][0][1])
        else:
            assert got == want


# -------------------------------------------------- shm ring protocol
_RING_DTYPES = ("float32", "float64", "int32", "int64")


@settings(max_examples=50)
@given(st.tuples(st.integers(0, 6), st.integers(0, 6)), st.integers(0, 3))
def test_shm_descriptor_round_trip_and_pickle_stability(shape, dti):
    """For ANY shape (empty included) and serving dtype: write -> read
    through the ring is bit-identical, and the descriptor survives the
    mp.Queue pickle hop as a *tuple* (is_shm_descriptor keys on tuple
    type — a pickle that thawed it as a list would silently ship the
    descriptor to the kernel as data)."""
    dtype = _RING_DTYPES[dti]
    ring = ShmRing(capacity=4096)
    reader = ShmRingReader(ring.name)
    try:
        rng = np.random.default_rng(shape[0] * 29 + shape[1] * 7 + dti)
        arr = (rng.normal(size=shape) * 100).astype(dtype)
        desc = ring.write(arr)
        assert desc is not None and is_shm_descriptor(desc)
        thawed = pickle.loads(pickle.dumps(desc))
        assert is_shm_descriptor(thawed)
        got = reader.read(thawed)
        assert got.dtype == arr.dtype and got.shape == arr.shape
        np.testing.assert_array_equal(got, arr)
        # control tuples of the same arity must never be mistaken for one
        assert not is_shm_descriptor(("batch", 1, [], (), ""))
    finally:
        reader.close()
        ring.dispose()


@settings(max_examples=25)
@given(st.lists(st.integers(0, 300), min_size=1, max_size=32),
       st.integers(1, 6))
def test_shm_ring_fifo_allocation_invariants(sizes, window):
    """For ANY payload-size sequence under a FIFO release cadence:
    every granted slot is 64-aligned, in-bounds, never wraps
    mid-payload, and never overlaps a live (unreleased) allocation; a
    write either fits entirely or returns None (the inline-fallback
    signal) — and after releases it must succeed again, so capacity
    pressure can only slow the ring down, never wedge or corrupt it."""
    align, cap = 64, 1024
    ring = ShmRing(capacity=cap)
    reader = ShmRingReader(ring.name)
    try:
        live = []  # (desc, alloc, expected payload), oldest first

        def drain_one():
            desc, _, want = live.pop(0)
            np.testing.assert_array_equal(reader.read(desc), want)

        for i, sz in enumerate(sizes):
            arr = np.full(sz, (i * 37 + sz) % 251, np.uint8)
            desc = ring.write(arr)
            while desc is None and live:
                drain_one()
                desc = ring.write(arr)
            assert desc is not None, "empty ring refused a fitting payload"
            off = desc[1]
            alloc = max(-(-sz // align) * align, align)
            assert off % align == 0
            assert off + sz <= cap  # never wraps mid-payload
            for other, oalloc, _ in live:
                o = other[1]
                assert off + alloc <= o or o + oalloc <= off, (
                    "granted slot overlaps a live allocation")
            live.append((desc, alloc, arr))
            if len(live) > window:
                drain_one()
        while live:
            drain_one()
    finally:
        reader.close()
        ring.dispose()


def test_worker_config_wire_round_trip():
    """The handshake payload: WorkerConfig (policy included) must
    survive to_wire -> frame -> from_wire exactly."""
    from repro.launch.transport import WorkerConfig
    policy = BucketPolicy(max_batch=16, mode="merge", merge_below=3,
                          col_class=2, col_max=8, pin_capacity=True)
    cfg = WorkerConfig(chunk=512, backend="jnp", dtype="float32",
                       policy=policy, max_pending=64, plan_cache=32,
                       linger_s=0.25, stage_depth=48, pipeline_depth=4,
                       x64=False, pin_workers=True)
    wire = FrameDecoder().feed(
        encode_frame(("hello", 0, cfg.to_wire())))[0][2]
    back = WorkerConfig.from_wire(wire)
    assert back == cfg
    assert back.policy == policy
