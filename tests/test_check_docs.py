"""The doc-consistency gate (tools/check_docs.py) under test.

Two directions: the live repo must be clean (this is the same check CI's
``lint`` job runs, so a doc edit that drifts from ``det_serve``'s
argparse fails here first, with pytest's diagnostics), and a fixture
tree proves the gate actually *catches* the two drift modes it promises
to — a documented flag det_serve does not define, and a ``[[NAME]]``
cross-reference with no ``NAME.md`` behind it.
"""

from pathlib import Path

from tools import check_docs

REPO = Path(__file__).resolve().parents[1]


def test_live_repo_is_clean():
    findings, stats = check_docs.check_docs(REPO)
    assert findings == []
    # the gate is only meaningful if it actually scanned something
    assert stats["docs"] >= 7           # README + the six DESIGN_* docs
    assert stats["flags_checked"] >= 10
    assert stats["xrefs_checked"] >= 6  # README's architecture map


def test_live_argparse_surface():
    flags = check_docs.argparse_flags(REPO / check_docs.DET_SERVE_REL)
    # spot-check flags the README's recipes lean on
    for f in ("--listen", "--connect", "--workers", "--shm",
              "--grad-frac", "--verify"):
        assert f in flags


def _fixture(tmp_path: Path, readme: str) -> Path:
    serve = tmp_path / "src" / "repro" / "launch"
    serve.mkdir(parents=True)
    (serve / "det_serve.py").write_text(
        "import argparse\n"
        "ap = argparse.ArgumentParser()\n"
        'ap.add_argument("--num", type=int)\n'
        'ap.add_argument("--verify", action="store_true")\n')
    (tmp_path / "README.md").write_text(readme)
    (tmp_path / "DESIGN_REAL.md").write_text("# real doc\n")
    return tmp_path


def test_catches_unknown_flag(tmp_path):
    root = _fixture(tmp_path, "Run `det_serve --num 4 --frobnicate`.\n")
    findings, _ = check_docs.check_docs(root)
    assert len(findings) == 1
    assert "--frobnicate" in findings[0] and "README.md:1" in findings[0]


def test_catches_dangling_xref(tmp_path):
    root = _fixture(tmp_path, "See [[DESIGN_REAL]] and [[DESIGN_GONE]].\n")
    findings, _ = check_docs.check_docs(root)
    assert len(findings) == 1
    assert "DESIGN_GONE" in findings[0]


def test_fenced_continuation_is_one_command(tmp_path):
    """A backslash-wrapped det_serve command is judged whole: known
    flags on the continuation line pass, unknown ones fail — and a
    non-det_serve line sharing the block stays out of scope."""
    ok = _fixture(tmp_path, "```bash\n"
                            "python -m repro.launch.det_serve --num 4 \\\n"
                            "    --verify\n"
                            "pytest --lf\n"
                            "```\n")
    findings, stats = check_docs.check_docs(ok)
    assert findings == [] and stats["flags_checked"] == 2
    bad = _fixture(tmp_path / "bad",
                   "```bash\n"
                   "python -m repro.launch.det_serve --num 4 \\\n"
                   "    --explode\n"
                   "```\n")
    findings, _ = check_docs.check_docs(bad)
    assert len(findings) == 1 and "--explode" in findings[0]
    assert "README.md:2" in findings[0]


def test_cli_exit_codes(tmp_path, capsys):
    assert check_docs.main(["--root", str(REPO)]) == 0
    root = _fixture(tmp_path, "`det_serve --nope`\n")
    assert check_docs.main(["--root", str(root)]) == 1
    err = capsys.readouterr().err
    assert "--nope" in err
