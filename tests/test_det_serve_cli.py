"""Subprocess smoke tests for the det_serve CLI.

The CLI is the only entry point operators touch and it had no test at
all: a broken argparse wiring, a stats key renamed out from under the
print block, or a front that hangs at close would all ship silently.
Each case runs the real ``python -m repro.launch.det_serve`` in a
subprocess (the front additionally spawn-forks its own workers from
there — exactly the production topology) and asserts exit 0 plus
parseable stats lines.
"""

import os
import re
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
COMMON = ["--num", "12", "--max-m", "3", "--max-n", "8", "--seed", "1"]


def _run(*extra, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.det_serve", *COMMON, *extra],
        capture_output=True, text=True, timeout=timeout, cwd=REPO, env=env)


def _total_line(stdout: str) -> tuple[int, float]:
    """Parse the closing ``total,<N> mats,<wall>s,<rate> mats/s`` line."""
    m = re.search(r"^total,(\d+) mats,([0-9.]+)s,([0-9.]+) mats/s$",
                  stdout, re.MULTILINE)
    assert m, f"no total line in:\n{stdout}"
    return int(m.group(1)), float(m.group(3))


def test_cli_async_queue_smoke():
    r = _run("--verify")
    assert r.returncode == 0, r.stderr
    num, rate = _total_line(r.stdout)
    assert num == 12 and rate > 0
    assert "plan_cache=" in r.stdout
    assert re.search(r"worst rel err [0-9.e+-]+", r.stdout)


def test_cli_sync_drain_smoke():
    r = _run("--sync")
    assert r.returncode == 0, r.stderr
    assert _total_line(r.stdout)[0] == 12
    assert "det_serve[sync]" in r.stdout


def _check_front_output(stdout: str, workers: int, label: str):
    assert _total_line(stdout)[0] == 12
    assert f"det_serve[{label}" in stdout
    m = re.search(r"^front: workers=(\d+)/(\d+) rerouted=(\d+) "
                  r"worker_deaths=(\d+) shed=(\d+)", stdout, re.MULTILINE)
    assert m, f"no front stats line in:\n{stdout}"
    assert m.group(1) == m.group(2) == str(workers)
    assert m.group(4) == "0"  # a clean run kills nobody
    # one per-worker stats row each, all requests accounted for
    rows = re.findall(r"^(\d+),(\d+),(\d+),(\d+),(\d+),(\d+),(\d+)$",
                      stdout, re.MULTILINE)
    assert len(rows) == workers
    assert sum(int(x[2]) for x in rows) == 12  # completed column


@pytest.mark.parametrize("workers", [1, 2])
def test_cli_front_smoke(workers):
    r = _run("--workers", str(workers), "--verify")
    assert r.returncode == 0, r.stderr
    _check_front_output(r.stdout, workers, f"front x{workers}")


@pytest.mark.parametrize("workers", [1, 2])
def test_cli_front_shm_smoke(workers):
    """``--workers N --shm``: the zero-copy same-host ring end to end
    through the CLI — exit 0, shm label in the report, every request
    completed and verified against the oracle."""
    r = _run("--workers", str(workers), "--shm", "--verify")
    assert r.returncode == 0, r.stderr
    _check_front_output(r.stdout, workers, f"front x{workers}@shm")
    assert re.search(r"worst rel err [0-9.e+-]+", r.stdout)


@pytest.mark.parametrize("workers", [1, 2])
def test_cli_listen_connect_loopback(workers):
    """The two-command multi-host recipe, loopback edition: worker
    daemons (``--listen``, separate processes) + a front (``--connect``)
    — exit 0 on both sides, stats parsed, results verified against the
    oracle."""
    from repro.launch.transport import spawn_worker_daemon
    daemons = []
    try:
        for _ in range(workers):
            daemons.append(spawn_worker_daemon())
        addrs = ",".join(a for _, a in daemons)
        r = _run("--connect", addrs, "--verify")
        assert r.returncode == 0, r.stderr
        _check_front_output(r.stdout, workers, f"front x{workers}@socket")
        assert re.search(r"worst rel err [0-9.e+-]+", r.stdout)
        for proc, _ in daemons:
            assert proc.wait(timeout=120) == 0  # --serve-once: clean exit
    finally:
        for proc, _ in daemons:
            proc.kill()


def test_launch_env_wrapper_sets_host_devices():
    """``tools/launch_env.sh`` is pure environment + exec: argv runs
    unchanged, DET_HOST_DEVICES lands in XLA_FLAGS (carving the CPU
    into N XLA devices), and without knobs it is a transparent no-op
    wrapper (tcmalloc preload only fires when the library exists)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    env["DET_HOST_DEVICES"] = "2"
    r = subprocess.run(
        ["tools/launch_env.sh", sys.executable, "-c",
         "import os, jax; print(os.environ['XLA_FLAGS']); "
         "print(jax.device_count())"],
        capture_output=True, text=True, timeout=300, cwd=REPO, env=env)
    assert r.returncode == 0, r.stderr
    assert "--xla_force_host_platform_device_count=2" in r.stdout
    assert r.stdout.strip().endswith("2")
    env.pop("DET_HOST_DEVICES")
    r = subprocess.run(["tools/launch_env.sh", sys.executable, "-c",
                        "print('passthrough')"],
                       capture_output=True, text=True, timeout=300,
                       cwd=REPO, env=env)
    assert r.returncode == 0, r.stderr
    assert r.stdout.strip() == "passthrough"
