"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step + a decode step on CPU; output shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, list_archs
from repro.launch.steps import (init_train_state, make_decode_step,
                                make_prefill_step, make_train_step)
from repro.models import build_model
from repro.optim import AdamWConfig

B, S = 2, 16


def _batch(cfg, key):
    kt, kp = jax.random.split(jax.random.PRNGKey(7))
    toks = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.prefix_embeds:
        batch["prefix_embeds"] = 0.02 * jax.random.normal(
            kp, (B, cfg.n_patches, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        batch["frame_embeds"] = 0.02 * jax.random.normal(
            kp, (B, cfg.n_frames, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.family == get_config(arch).family  # same family as full
    opt = AdamWConfig(lr=1e-3)
    model, params, opt_state = init_train_state(
        cfg, opt, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    # forward
    if cfg.family == "audio":
        logits, _ = model.forward(params, batch["tokens"],
                                  batch["frame_embeds"])
        want_s = S
    else:
        logits, _ = model.forward(params, batch["tokens"],
                                  batch.get("prefix_embeds"))
        want_s = S + (cfg.n_patches if cfg.prefix_embeds else 0)
    assert logits.shape == (B, want_s, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()
    # train step (jitted), loss decreases over a couple of steps
    step = jax.jit(make_train_step(model, opt))
    params1, opt_state, m1 = step(params, opt_state, batch)
    params2, _, m2 = step(params1, opt_state, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"]) + 0.1  # same-batch step
    assert float(m1["grad_norm"]) > 0


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_decode(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    max_len = S + (cfg.n_patches if cfg.prefix_embeds else 0) + 4
    if cfg.family == "audio":
        cache = model.init_cache(B, max_len)
        cache = model.warm_cross_cache(params, cache,
                                       batch["frame_embeds"])
        logits, cache = model.decode_step(params, cache,
                                          batch["tokens"][:, :1])
    else:
        prefill = make_prefill_step(model, max_len)
        out = prefill(params, batch)
        logits, cache = out
        decode = jax.jit(make_decode_step(model))
        logits, cache = decode(params, cache, {"tokens":
                                               batch["tokens"][:, :1]})
    assert logits.shape == (B, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()
