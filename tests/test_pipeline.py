"""GPipe pipeline: schedule shape + numerical equivalence on a real
multi-device mesh (subprocess with forced device count)."""

import os
import subprocess
import sys
import textwrap

from repro.parallel.pipeline import bubble_fraction, gpipe_schedule

REPO = os.path.dirname(os.path.dirname(__file__))


def test_schedule_covers_all_cells_once():
    S, M = 4, 6
    sched = gpipe_schedule(S, M)
    assert len(sched) == S * M
    assert {(s, m) for _, s, m in sched} == {(s, m) for s in range(S)
                                             for m in range(M)}
    # microbatch m hits stage s exactly at step s + m (no overtaking)
    for t, s, m in sched:
        assert t == s + m
    assert abs(bubble_fraction(4, 6) - 3 / 9) < 1e-9


PIPE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.parallel.pipeline import pipeline_apply
    S, M, B, D = 4, 8, 2, 16
    mesh = Mesh(np.array(jax.devices()).reshape(S), ("stage",))
    key = jax.random.PRNGKey(0)
    Ws = jax.random.normal(key, (S, D, D)) * 0.3
    def stage_fn(w, h):
        return jnp.tanh(h @ w)
    x = jax.random.normal(jax.random.PRNGKey(1), (M, B, D))
    got = pipeline_apply(stage_fn, Ws, x, mesh=mesh, stage_axis="stage",
                         n_micro=M)
    # sequential reference
    want = x
    for s in range(S):
        want = jnp.tanh(want @ Ws[s])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    print("PIPELINE_OK")
""")


def test_pipeline_matches_sequential_4stage():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", PIPE],
                         capture_output=True, text=True, env=env, cwd=REPO)
    assert "PIPELINE_OK" in out.stdout, (out.stdout, out.stderr[-2000:])
