"""Batched multi-matrix workload: radic_det_batched (jnp + pallas +
mesh), the shape-bucketed det_serve batcher, and arrival-order/padding
invariants."""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (radic_det, radic_det_batched,
                        radic_det_batched_distributed, radic_det_oracle)
from repro.launch.det_serve import (bucket_by_shape, drain_queue,
                                    pad_capacity)

REPO = os.path.dirname(os.path.dirname(__file__))

# ≥ 3 heterogeneous shape buckets, exact-oracle checked (small n)
SHAPES = [(2, 6), (3, 8), (1, 5), (4, 9)]


@pytest.mark.parametrize("m,n", SHAPES)
def test_batched_matches_loop_and_oracle(m, n, rng):
    As = rng.normal(size=(5, m, n)).astype(np.float32)
    got = np.asarray(radic_det_batched(jnp.asarray(As), chunk=32))
    loop = np.array([float(radic_det(jnp.asarray(A), chunk=32))
                     for A in As])
    want = np.array([radic_det_oracle(A) for A in As])
    np.testing.assert_allclose(got, loop, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("m,n", [(2, 6), (3, 7), (1, 5)])
def test_batched_pallas_backend(m, n, rng):
    As = rng.normal(size=(4, m, n)).astype(np.float32)
    got = np.asarray(radic_det_batched(jnp.asarray(As), backend="pallas"))
    want = np.array([radic_det_oracle(A) for A in As])
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_batched_edge_cases(rng):
    # m > n: paper defines det = 0
    As = rng.normal(size=(3, 4, 2)).astype(np.float32)
    assert (np.asarray(radic_det_batched(jnp.asarray(As))) == 0).all()
    # empty batch
    assert radic_det_batched(jnp.zeros((0, 2, 4))).shape == (0,)
    # non-3D input
    with pytest.raises(ValueError):
        radic_det_batched(jnp.zeros((2, 4)))


def test_batched_distributed_single_device(rng):
    As = rng.normal(size=(4, 3, 8)).astype(np.float32)
    want = np.array([radic_det_oracle(A) for A in As])
    for backend in ("jnp", "pallas"):
        got = np.asarray(radic_det_batched_distributed(
            jnp.asarray(As), backend=backend, chunk=16))
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_bucketing_and_pad_capacity():
    mats = [np.zeros((2, 5)), np.zeros((3, 7)), np.zeros((2, 5)),
            np.zeros((1, 4))]
    buckets = bucket_by_shape(mats)
    assert buckets == {(1, 4): [3], (2, 5): [0, 2], (3, 7): [1]}
    with pytest.raises(ValueError):
        bucket_by_shape([np.zeros((2, 2, 2))])
    assert [pad_capacity(k, 64) for k in (1, 2, 3, 5, 64, 100)] == \
        [1, 2, 4, 8, 64, 64]


def test_empty_bucket_dispatches_nothing():
    """Regression: pad_capacity(0, max_batch) used to return 1, so an
    empty bucket dispatched one phantom all-zero padded row.  Empty
    buckets must have capacity 0 and dispatch nothing."""
    assert pad_capacity(0, 64) == 0
    assert pad_capacity(-3, 64) == 0
    dets, stats = drain_queue([])
    assert dets == [] and stats == {}


def test_drain_queue_order_padding_stats(rng):
    # shuffled heterogeneous queue across 4 shape buckets, group sizes
    # that force zero-padding (3 -> capacity 4, 5 -> 8, ...)
    mats = []
    for m, n in SHAPES:
        for _ in range(3 + m):
            mats.append(rng.normal(size=(m, n)).astype(np.float32))
    order = rng.permutation(len(mats))
    mats = [mats[i] for i in order]
    dets, stats = drain_queue(mats, chunk=64, max_batch=8)
    for A, got in zip(mats, dets):
        want = radic_det_oracle(np.asarray(A))
        assert abs(got - want) <= 2e-3 * max(1.0, abs(want))
    assert set(stats) == set(SHAPES)
    assert sum(s["count"] for s in stats.values()) == len(mats)
    for s in stats.values():
        assert s["dispatches"] >= 1 and s["wall_s"] > 0


BATCHED_MULTIDEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core import radic_det_batched, radic_det_oracle
    from repro.core.distributed import radic_det_batched_distributed
    assert len(jax.devices()) == 8
    rng = np.random.default_rng(5)
    As = rng.normal(size=(6, 3, 9)).astype(np.float32)
    want = np.array([radic_det_oracle(a) for a in As])
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    # rank-space over the whole mesh, batch replicated
    got = np.asarray(radic_det_batched(jnp.asarray(As), mesh=mesh, chunk=16))
    assert np.allclose(got, want, rtol=2e-3, atol=2e-3), (got, want)
    # batch over "data", rank space over "model"; both backends
    for be in ("jnp", "pallas"):
        got = np.asarray(radic_det_batched_distributed(
            jnp.asarray(As), mesh=mesh, batch_axis="data", chunk=16,
            backend=be))
        assert np.allclose(got, want, rtol=2e-3, atol=2e-3), (be, got, want)
    print("BATCHED_MULTIDEV_OK")
""")


def test_batched_eight_device_mesh():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", BATCHED_MULTIDEV],
                         capture_output=True, text=True, env=env, cwd=REPO)
    assert "BATCHED_MULTIDEV_OK" in out.stdout, (out.stdout, out.stderr[-2000:])
