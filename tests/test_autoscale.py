"""Battery for the elastic serving pool: live worker join, the SLO
autoscaler, and straggler-aware health.

The load-bearing invariant is the same one every serving tier above the
DetQueue carries: membership changes must be invisible in the results.
A worker that joins mid-workload (via ``DetFront.grow`` or by dialing
the front's ``--accept`` listener) and a worker retired by the
autoscaler or the straggler sweep may only change *where* plans run —
per-request determinants stay bit-identical to the 1-process
``DetQueue`` because the sticky placer never moves an already-assigned
plan family and retirement is the graceful drain.

The controller itself is tested synchronously: ``Autoscaler.tick``
takes an injected snapshot + clock, so hysteresis (consecutive-tick
thresholds, cooldown windows) is pinned deterministically against a
stub front, while the scale-up/scale-down legs drive a real local
pool.
"""

import threading
import time

import numpy as np
import pytest

from repro.launch.autoscale import (Autoscaler, AutoscalePolicy,
                                    default_max_workers)
from repro.launch.det_front import DetFront
from repro.launch.det_queue import BucketPolicy, DetQueue
from repro.launch.transport import run_worker_client

CHUNK = 128
CAP = 8
SHAPES = [(1, 4), (2, 5), (2, 6), (3, 7), (3, 9), (4, 10), (4, 2)]
PINNED = BucketPolicy(max_batch=CAP, mode="merge", pin_capacity=True)


def _mats(rng, num):
    out = []
    for _ in range(num):
        m, n = SHAPES[int(rng.integers(0, len(SHAPES)))]
        out.append(rng.normal(size=(m, n)).astype(np.float32))
    return out


def _queue_reference(mats, policy=PINNED):
    with DetQueue(chunk=CHUNK, policy=policy) as q:
        dets, _ = q.serve(mats, timeout=300)
    return dets


def _wait_alive_count(front, want, timeout=60.0):
    deadline = time.monotonic() + timeout
    while len(front.alive_workers) != want:
        assert time.monotonic() < deadline, \
            f"alive={front.alive_workers}, want {want} workers"
        time.sleep(0.05)


def _snap(alive, *, pending=0, shed=0, submitted=0, lat=None, load=None):
    """Synthetic ``snapshot()['front']`` for deterministic tick tests."""
    per = pending // max(1, alive)
    return {"front": {
        "workers_alive": alive,
        "pending": {i: per for i in range(alive)},
        "shed": shed,
        "submitted": submitted,
        "latency_ema_s": dict(lat or {}),
        "plan_load": dict(load) if load is not None
        else {i: 0.0 for i in range(alive)},
    }}


class _StubFront:
    """Records actuator calls; never spawns anything."""

    def __init__(self):
        self.grown = 0
        self.retired = []

    def grow(self, count=1):
        self.grown += count
        return list(range(100, 100 + count))

    def retire_worker(self, wid):
        self.retired.append(wid)


# ----------------------------------------------------------- live join
def test_join_mid_workload_bit_identical(rng):
    """A worker that dials the ``accept`` listener mid-workload (the
    ``det_serve --join`` path, run in-thread here) plus a ``grow()``
    worker must leave every result bit-identical to the 1-process
    queue: admission is atomic and the sticky placer keeps assigned
    families put."""
    mats = _mats(rng, 24)
    want = _queue_reference(mats)
    with DetFront(workers=1, chunk=CHUNK, policy=PINNED,
                  accept="127.0.0.1:0") as front:
        first = front.submit_many(mats[:12])
        assert front.grow(1) == [1]
        joiner = threading.Thread(
            target=run_worker_client, args=(front.accept_address,),
            kwargs={"log": lambda *a, **k: None}, daemon=True)
        joiner.start()
        _wait_alive_count(front, 3)
        rest = front.submit_many(mats[12:])
        got = [f.result(timeout=300) for f in first + rest]
        snap = front.snapshot()
        assert snap["front"]["joined"] == 2
        assert snap["front"]["workers_alive"] == 3
    joiner.join(timeout=30)
    assert got == want


def test_placer_sticky_across_grow(rng):
    """Families assigned before a grow stay on their owner afterwards
    (the ring-level monotone property, observed end-to-end)."""
    mats = _mats(rng, 16)
    with DetFront(workers=2, chunk=CHUNK, policy=PINNED) as front:
        front.serve(mats, timeout=300)
        owners = {s: front.owner_of(s) for s in SHAPES}
        front.grow(1)
        _wait_alive_count(front, 3)
        assert {s: front.owner_of(s) for s in SHAPES} == owners


# ----------------------------------------------------------- autoscaler legs
def test_autoscaler_scales_up_under_backlog_and_down_on_idle(rng):
    """Injected breach snapshots make the controller grow a real local
    pool 1→2; injected idle snapshots drain it back to 1; results stay
    bit-identical throughout."""
    mats = _mats(rng, 16)
    want = _queue_reference(mats)
    with DetFront(workers=1, chunk=CHUNK, policy=PINNED) as front:
        scaler = Autoscaler(front, min_workers=1, max_workers=2,
                            up_ticks=2, idle_ticks=2, cooldown_s=5.0)
        busy = dict(pending=64, submitted=64)
        assert scaler.tick(_snap(1, **busy), now=0.0) == "hold"
        assert scaler.tick(_snap(1, **busy), now=1.0) == "up"
        _wait_alive_count(front, 2)
        assert front.serve(mats, timeout=300)[0] == want

        assert scaler.tick(_snap(2, submitted=64), now=2.0) == "hold"
        # within cooldown: idle ticks accumulate but no action fires
        assert scaler.tick(_snap(2, submitted=64), now=3.0) == "hold"
        assert scaler.tick(_snap(2, submitted=64), now=20.0) == "down"
        _wait_alive_count(front, 1)
        assert scaler.scaled_up == 1 and scaler.scaled_down == 1
        # the survivor still serves the full pool bit-identically
        assert front.serve(mats, timeout=300)[0] == want


def test_autoscaler_loop_thread_runs_and_stops(rng):
    """The background loop drives real snapshots without flapping an
    idle pool below min_workers, and stop() joins cleanly."""
    with DetFront(workers=1, chunk=CHUNK, policy=PINNED) as front:
        with Autoscaler(front, min_workers=1, max_workers=2,
                        interval_s=0.05, idle_ticks=2,
                        cooldown_s=0.0) as scaler:
            front.serve(_mats(rng, 8), timeout=300)
            time.sleep(0.5)
        assert len(front.alive_workers) == 1  # never below the floor
        assert scaler.scaled_down == 0


# -------------------------------------------------------------- hysteresis
def test_autoscaler_no_flap_on_alternating_load():
    """Alternating breach/idle observations never act: both hysteresis
    counters reset on every sign change."""
    stub = _StubFront()
    a = Autoscaler(stub, min_workers=1, max_workers=4,
                   up_ticks=2, idle_ticks=2, cooldown_s=0.0)
    for i in range(10):
        snap = (_snap(2, pending=64, submitted=64 + i) if i % 2 == 0
                else _snap(2, submitted=64 + i))
        assert a.tick(snap, now=float(i)) == "hold"
    assert stub.grown == 0 and stub.retired == []


def test_autoscaler_cooldown_bounds_action_rate():
    """Persistent breach: exactly one scale-up per cooldown window, no
    matter how many ticks observe the breach."""
    stub = _StubFront()
    a = Autoscaler(stub, min_workers=1, max_workers=8,
                   up_ticks=2, cooldown_s=10.0)
    actions = [a.tick(_snap(2, pending=640, submitted=n), now=float(n))
               for n in range(12)]
    assert actions.count("up") == 2  # t=1 and t=11, not one per tick
    assert stub.grown == 2


def test_autoscaler_respects_bounds():
    stub = _StubFront()
    a = Autoscaler(stub, min_workers=1, max_workers=2,
                   up_ticks=1, idle_ticks=1, cooldown_s=0.0)
    # at max: breach holds
    assert a.tick(_snap(2, pending=640, submitted=1), now=0.0) == "hold"
    # at min: idle holds
    assert a.tick(_snap(1), now=1.0) == "hold"
    assert stub.grown == 0 and stub.retired == []
    # scale-down picks the least plan-loaded worker deterministically
    a2 = Autoscaler(stub, min_workers=1, max_workers=4,
                    up_ticks=1, idle_ticks=1, cooldown_s=0.0)
    assert a2.tick(_snap(3, load={0: 5.0, 1: 1.0, 2: 3.0}),
                   now=0.0) == "down"
    assert stub.retired == [1]


def test_autoscaler_latency_slo_trigger():
    stub = _StubFront()
    a = Autoscaler(stub, min_workers=1, max_workers=4, slo_latency_s=0.5,
                   up_ticks=1, cooldown_s=0.0)
    snap = _snap(2, submitted=1, lat={0: 0.1, 1: 0.9})
    assert a.tick(snap, now=0.0) == "up"
    assert stub.grown == 1


def test_autoscale_policy_validation():
    with pytest.raises(ValueError):
        AutoscalePolicy(min_workers=0)
    with pytest.raises(ValueError):
        AutoscalePolicy(min_workers=3, max_workers=2)
    with pytest.raises(ValueError):
        AutoscalePolicy(cold_hit_rate=1.5)
    with pytest.raises(ValueError):
        AutoscalePolicy(cold_grace_requests=-1)
    assert default_max_workers() >= 1


# ------------------------------------------------- plan-cache temperature
def test_autoscaler_cold_set_from_hit_rates():
    """The warm-start signal (DESIGN_PERSIST.md): a worker still paying
    compiles (low engine+store hit rate) is cold; a store-prefilled
    joiner (store_hits ≈ misses) and a long-warmed worker (past the
    grace window) are not."""
    a = Autoscaler(_StubFront(), cold_hit_rate=0.5, cold_grace_requests=64)

    def pc(hits, misses, store_hits=0):
        return {"plan_cache": {"hits": hits, "misses": misses,
                               "store_hits": store_hits}}
    workers = {
        0: pc(0, 4),            # cold joiner compiling from scratch
        1: pc(0, 4, 4),         # store-prefilled: every miss was a hit
        2: pc(100, 10),         # mature worker, past the grace window
        3: pc(1, 3, 1),         # rate 0.5: at the threshold, not below
        4: {},                  # no plan_cache section: not judged
    }
    assert a._cold_set(workers) == {0}


def test_autoscaler_tick_marks_cold_workers_on_front():
    """Every tick pushes the cold set to the front (which shields those
    workers from the straggler sweep); fronts without the hook and
    snapshots without a workers section both degrade gracefully."""
    class _ColdStub(_StubFront):
        def __init__(self):
            super().__init__()
            self.cold_calls = []

        def mark_cold_workers(self, wids):
            self.cold_calls.append(set(wids))

    stub = _ColdStub()
    a = Autoscaler(stub, up_ticks=1, cooldown_s=0.0)
    snap = _snap(2, submitted=4)
    snap["workers"] = {
        0: {"plan_cache": {"hits": 0, "misses": 3, "store_hits": 0}},
        1: {"plan_cache": {"hits": 9, "misses": 1, "store_hits": 0}},
    }
    a.tick(snap, now=0.0)
    assert stub.cold_calls == [{0}]
    # worker 0 warms up: the next tick clears it
    snap["workers"][0]["plan_cache"] = {"hits": 9, "misses": 3,
                                        "store_hits": 0}
    a.tick(snap, now=1.0)
    assert stub.cold_calls == [{0}, set()]
    # plain stub (no hook) + snapshot without workers: still no crash
    assert Autoscaler(_StubFront()).tick(_snap(1), now=0.0) == "hold"


def test_cold_worker_shielded_from_straggler_sweep(rng):
    """A cold-marked worker's high latency EMA (it is compiling, not
    slow) must not get it drained; once the mark clears, the sweep
    treats it like any other peer."""
    mats = _mats(rng, 16)
    with DetFront(workers=3, chunk=CHUNK, policy=PINNED,
                  straggler_factor=2.0, straggler_warmup=4,
                  straggler_cooldown_s=0.0) as front:
        front.serve(mats, timeout=300)
        victim = front.alive_workers[0]
        with front._lock:  # seed measured EMAs deterministically
            for w in front._workers:
                w.timer.ema = 10.0 if w.id == victim else 0.1
                w.timer.n = 10
        front.mark_cold_workers([victim])
        front._sweep_stragglers(time.monotonic())
        snap = front.snapshot()
        assert snap["front"]["stragglers_drained"] == 0
        assert snap["front"]["cold_workers"] == [victim]
        assert victim in front.alive_workers
        front.mark_cold_workers([])  # warm now: ordinary health rules
        front._sweep_stragglers(time.monotonic())
        _wait_alive_count(front, 2)
        assert front.snapshot()["front"]["stragglers_drained"] == 1


# ------------------------------------------------------- straggler health
def test_straggler_sweep_drains_slow_worker(rng):
    """A worker whose completion-latency EMA sits far above the median
    of its warmed peers is retired by the sweep — gracefully, so the
    pool keeps serving bit-identically on the survivors."""
    mats = _mats(rng, 16)
    want = _queue_reference(mats)
    with DetFront(workers=3, chunk=CHUNK, policy=PINNED,
                  straggler_factor=2.0, straggler_warmup=4,
                  straggler_cooldown_s=0.0) as front:
        assert front.serve(mats, timeout=300)[0] == want
        victim = front.alive_workers[0]
        with front._lock:  # seed measured EMAs deterministically
            for w in front._workers:
                w.timer.ema = 10.0 if w.id == victim else 0.1
                w.timer.n = 10
        front._sweep_stragglers(time.monotonic())
        _wait_alive_count(front, 2)
        snap = front.snapshot()
        assert snap["front"]["stragglers_drained"] == 1
        assert victim not in front.alive_workers
        assert front.serve(mats, timeout=300)[0] == want


def test_straggler_sweep_needs_quorum_and_cooldown(rng):
    """With a single warmed worker there is no peer median — the sweep
    must hold; and back-to-back sweeps inside the cooldown window drain
    at most one worker."""
    with DetFront(workers=2, chunk=CHUNK, policy=PINNED,
                  straggler_factor=2.0, straggler_warmup=4,
                  straggler_cooldown_s=3600.0) as front:
        with front._lock:
            w0, w1 = front._workers
            w0.timer.ema, w0.timer.n = 10.0, 10
            w1.timer.ema, w1.timer.n = 0.1, 0  # not warmed: no quorum
        front._sweep_stragglers(time.monotonic())
        assert front.snapshot()["front"]["stragglers_drained"] == 0
        with front._lock:
            w1.timer.n = 10  # warmed now: quorum of 2
        now = time.monotonic()
        front._sweep_stragglers(now)
        front._sweep_stragglers(now + 1.0)  # inside cooldown: no-op
        assert front.snapshot()["front"]["stragglers_drained"] == 1
        assert len(front.alive_workers) == 1
