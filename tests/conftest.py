"""Shared test config.

NOTE: do NOT set XLA_FLAGS / device-count overrides here — smoke tests and
benches must see the real single CPU device; only launch/dryrun.py forces
512 placeholder devices (in its own process).
"""

import numpy as np
import pytest

try:
    from hypothesis import settings
except ModuleNotFoundError:
    # hypothesis is an optional [test] extra; property-test modules fall
    # back to the seeded sampler in tests/_hyp_fallback.py.
    settings = None

if settings is not None:
    # Keep hypothesis fast on the single-core CI box.
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
