"""Shared test config.

NOTE: do NOT set XLA_FLAGS / device-count overrides here — smoke tests and
benches must see the real single CPU device; only launch/dryrun.py forces
512 placeholder devices (in its own process).
"""

import numpy as np
import pytest

try:
    from hypothesis import settings
except ModuleNotFoundError:
    # hypothesis is an optional [test] extra; property-test modules fall
    # back to the seeded sampler in tests/_hyp_fallback.py.
    settings = None

if settings is not None:
    # Keep hypothesis fast on the single-core CI box.
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _isolate_compilation_cache():
    """Opening a plan store points jax's process-global persistent
    compilation cache at ``<store>/xla-cache`` (DESIGN_PERSIST.md).  In
    tests the store is a tmp dir pytest deletes, which would leave every
    *later* test compiling against a vanished cache dir (a UserWarning
    per compile).  Restore the config — and drop jax's first-compile
    latch so the restore takes — whenever a test changed it."""
    import jax

    try:
        before = jax.config.jax_compilation_cache_dir
    except AttributeError:  # jax leg without the option: nothing to leak
        yield
        return
    yield
    if jax.config.jax_compilation_cache_dir != before:
        jax.config.update("jax_compilation_cache_dir", before)
        try:
            from jax._src import compilation_cache as _cc
            _cc.reset_cache()
        except Exception:
            pass
