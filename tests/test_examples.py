"""Subprocess smoke tests for the examples the README points readers at.

The examples are product surface — ``README.md`` sends a new reader to
``examples/quickstart.py`` in its first code block — but until now
nothing executed them in CI, so a drifted import or a renamed core
function would ship as a broken front door.  Each case runs the real
script as a subprocess (seeded, CPU-sized) and asserts exit 0 plus the
output markers the script's own asserts stand behind.

``test_signature_batched_matches_loop`` additionally pins the
retrieval rewrite's parity claim *in-process*: the one-dispatch
``radic_det_batched`` signature must reproduce the scalar-loop-of-
``radic_det`` signature it replaced (same flat evaluator, one slot per
rank — see DESIGN_GRAD.md for why the batched path is also the
gradient path).
"""

import importlib.util
import os
import re
import subprocess
import sys
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parents[1]


def _run_example(name: str, *extra: str, timeout: int = 600):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, str(REPO / "examples" / name), *extra],
        capture_output=True, text=True, timeout=timeout, cwd=REPO, env=env)


def _load_example(name: str):
    """Import an example script as a module (examples/ is not a
    package); its ``main()`` stays behind the ``__main__`` guard."""
    spec = importlib.util.spec_from_file_location(
        f"example_{name[:-3]}", REPO / "examples" / name)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_quickstart_smoke():
    """The one-page paper walkthrough: every evaluator (oracle, flat
    jnp, Pallas, mesh grains) prints a determinant for the same matrix,
    and the bigint grain-start demo still runs exactly."""
    r = _run_example("quickstart.py")
    assert r.returncode == 0, r.stderr
    assert "sum over C(9,4) = 126 signed minors" in r.stdout
    for label in ("oracle (numpy enumeration)", "flat jnp (rank-parallel)",
                  "fused Pallas kernel", "mesh-distributed grains"):
        m = re.search(re.escape(label) + r"\s*: (-?[0-9.]+)", r.stdout)
        assert m, f"missing {label!r} line in:\n{r.stdout}"
        assert abs(float(m.group(1)) - (-1.1201943)) < 1e-3


def test_retrieval_smoke():
    """The retrieval demo end to end: batched-vs-loop parity holds, and
    the gradient-refined re-rank beats (or ties) raw similarity — the
    script's own asserts enforce both; here we also parse the numbers
    so a silently-weakened assert would still fail."""
    r = _run_example("retrieval.py")
    assert r.returncode == 0, r.stderr
    m = re.search(r"parity: worst \|diff\| = ([0-9.e+-]+)", r.stdout)
    assert m and float(m.group(1)) <= 1e-5, r.stdout
    m = re.search(r"similarity (\d+)/12, gradient-refined (\d+)/12",
                  r.stdout)
    assert m, f"no accuracy line in:\n{r.stdout}"
    assert int(m.group(2)) >= int(m.group(1))
    assert int(m.group(2)) >= 10


def test_signature_batched_matches_loop():
    """Parity satellite, in-process: the batched signature equals the
    scalar-loop signature elementwise on fresh random feature matrices
    of *different* widths (the non-square point of the paper)."""
    import jax.numpy as jnp
    retrieval = _load_example("retrieval.py")
    rng = np.random.default_rng(7)
    for n in (13, 20, 31):
        feats = rng.normal(size=(retrieval.M, n)).astype(np.float32)
        batched = np.asarray(retrieval.signature(jnp.asarray(feats)))
        looped = retrieval.signature_loop(feats)
        np.testing.assert_allclose(batched, looped, atol=1e-5)
