"""Property tests: batched-vs-per-matrix parity of ``radic_det_batched``
on the degenerate shapes the serving tier leans on — square (m == n),
single-row (m == 1, single-column 1×1 minors), the (1, 1) corner, and
all-zero padded rows.

Runs under hypothesis when installed, else the seeded fallback sampler
(tests/_hyp_fallback.py) — same strategies, deterministic draws.
"""

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional extra — seeded-random fallback
    from _hyp_fallback import given, settings, st

import jax.numpy as jnp
import numpy as np

from repro.core import radic_det, radic_det_batched

SEEDS = st.integers(0, 2**31 - 1)


def _batch(seed, B, m, n):
    return np.random.default_rng(seed).normal(size=(B, m, n)) \
        .astype(np.float32)


def _loop(As, chunk):
    """Per-matrix reference through the non-batched entry point."""
    return np.array([float(radic_det(jnp.asarray(A), chunk=chunk))
                     for A in As])


@given(st.integers(1, 4), st.integers(1, 4), SEEDS)
def test_square_matches_linalg_det(m, B, seed):
    """m == n: one single minor, sign (−1)^(r+s) = +1 — Radic's definition
    collapses to the classical determinant."""
    As = _batch(seed, B, m, m)
    got = np.asarray(radic_det_batched(jnp.asarray(As), chunk=64))
    want = np.asarray(jnp.linalg.det(jnp.asarray(As)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(got, _loop(As, 64), rtol=1e-5, atol=1e-6)


@given(st.integers(1, 8), st.integers(1, 4), SEEDS)
def test_single_row_alternating_sum(n, B, seed):
    """m == 1: every minor is a single-column 1×1, so the determinant is
    the alternating sum a1 − a2 + a3 − … (r = 1, s_q = j)."""
    As = _batch(seed, B, 1, n)
    got = np.asarray(radic_det_batched(jnp.asarray(As), chunk=16))
    signs = (-1.0) ** np.arange(n, dtype=np.float64)
    want = (As[:, 0, :].astype(np.float64) * signs).sum(axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got, _loop(As, 16), rtol=1e-5, atol=1e-6)


@given(st.integers(1, 4), SEEDS)
def test_one_by_one_single_column(B, seed):
    """(1, 1): single column, single minor — det is the entry itself."""
    As = _batch(seed, B, 1, 1)
    got = np.asarray(radic_det_batched(jnp.asarray(As)))
    np.testing.assert_allclose(got, As[:, 0, 0], rtol=1e-6, atol=0)
    np.testing.assert_allclose(got, _loop(As, 16), rtol=1e-6, atol=0)


dims = st.tuples(st.integers(1, 3), st.integers(1, 6)).filter(
    lambda t: t[0] <= t[1])


@given(dims, st.integers(2, 3), st.integers(1, 2), SEEDS)
def test_zero_padded_rows_exact_and_inert(dims, B, pad, seed):
    """All-zero padded rows (the serve batcher's padding scheme) yield
    exactly 0.0, and the *real* rows are bit-identical whatever occupies
    the padding slots — batch composition cannot leak between elements.
    This is the invariant DetQueue's bit-determinism rests on."""
    m, n = dims
    As = _batch(seed, B, m, n)
    cap = B + pad
    stack = np.zeros((cap, m, n), np.float32)
    stack[:B] = As
    out = np.asarray(radic_det_batched(jnp.asarray(stack), chunk=32))
    assert (out[B:] == 0.0).all()
    # same capacity, different company in the padding slots
    stack2 = _batch(seed + 1, cap, m, n)
    stack2[:B] = As
    out2 = np.asarray(radic_det_batched(jnp.asarray(stack2), chunk=32))
    assert (out[:B] == out2[:B]).all()
    np.testing.assert_allclose(out[:B], _loop(As, 32), rtol=1e-5, atol=1e-6)
