"""Concurrency/determinism battery for the async pipelined DetQueue.

The load-bearing invariant: per-request results are independent of how
the pipeline happened to group, pad or overlap them.  With capacity
pinned (one program shape per bucket), a request's determinant is
bit-identical to a single-threaded :func:`repro.core.radic_det_batched`
call at the queue's canonical shape — no matter how many producer
threads raced, how buckets merged or how hot buckets split.
"""

import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DetEngine, radic_det_batched, radic_det_oracle
from repro.launch.det_queue import (BucketPolicy, DetQueue, LoadShedError,
                                    QueueClosedError, Request, pad_capacity,
                                    plan_buckets)

CAP = 8
CHUNK = 128

# heterogeneous pool: several m classes, non-class-aligned n, one m > n
SHAPES = [(1, 4), (2, 5), (2, 6), (3, 7), (3, 9), (4, 10), (4, 2)]


def _mats(rng, num):
    out = []
    for _ in range(num):
        m, n = SHAPES[int(rng.integers(0, len(SHAPES)))]
        out.append(rng.normal(size=(m, n)).astype(np.float32))
    return out


def _ref(A, shape, cap, chunk=CHUNK):
    """Single-threaded batched reference at a canonical shape + pinned
    capacity.  Row position and batch company are bit-irrelevant (see
    test_zero_padded_rows in tests/test_batched_props.py), so row 0 of a
    zero-padded stack is *the* reference value."""
    m, n = A.shape
    if m > n:
        return 0.0
    stack = np.zeros((cap, *shape), np.float32)
    stack[0, :m, :n] = A
    return float(np.asarray(
        radic_det_batched(jnp.asarray(stack), chunk=chunk))[0])


def _reqs(mats):
    return [Request(seq=i, array=A, shape=A.shape)
            for i, A in enumerate(mats)]


# ------------------------------------------------------------- pure planning
def test_plan_buckets_exact_shapes_and_split():
    pol = BucketPolicy(max_batch=4, mode="never")
    mats = [np.zeros((2, 5), np.float32)] * 7 + [np.zeros((3, 7), np.float32)]
    plans = plan_buckets(_reqs(mats), pol)
    shapes = sorted(p.shape for p in plans)
    assert shapes == [(2, 5), (2, 5), (3, 7)]  # 7 -> 4+3 slices
    assert sorted(len(p.requests) for p in plans) == [1, 3, 4]
    for p in plans:
        # exact_capacity default: no padded batch rows, ever (the AOT
        # executable cache makes one program per exact size affordable)
        assert p.capacity == len(p.requests)
        assert not p.merged
    # FIFO within a bucket: slices preserve submit order
    two_five = [p for p in plans if p.shape == (2, 5)]
    seqs = [r.seq for p in two_five for r in p.requests]
    assert seqs == sorted(seqs)


def test_plan_buckets_pow2_capacity_mode():
    pol = BucketPolicy(max_batch=4, mode="never", exact_capacity=False)
    mats = [np.zeros((2, 5), np.float32)] * 7
    plans = plan_buckets(_reqs(mats), pol)
    assert [p.capacity for p in plans] == \
        [pad_capacity(len(p.requests), 4) for p in plans] == [4, 4]


def test_plan_buckets_forced_merge_groups_same_m():
    pol = BucketPolicy(max_batch=8, mode="merge", col_class=4, col_max=16)
    mats = [np.zeros((2, 5), np.float32), np.zeros((2, 6), np.float32),
            np.zeros((2, 7), np.float32), np.zeros((3, 7), np.float32),
            np.zeros((2, 8), np.float32)]  # already canonical: not "merged"
    plans = plan_buckets(_reqs(mats), pol)
    assert sorted(p.shape for p in plans) == [(2, 8), (3, 8)]
    by_shape = {p.shape: p for p in plans}
    # all four m=2 requests coalesced into the one (2, 8) batch, but only
    # the three column-padded ones count as merged — the native (2, 8)
    # request must not inflate the stat
    assert len(by_shape[(2, 8)].requests) == 4
    assert by_shape[(2, 8)].merged_count == 3
    assert by_shape[(3, 8)].merged_count == 1
    assert by_shape[(2, 8)].merged and by_shape[(3, 8)].merged


def test_plan_buckets_auto_merges_only_underfilled_under_load():
    pol = BucketPolicy(max_batch=8, mode="auto", merge_below=4,
                       merge_depth=8, col_class=4)
    # full bucket (2, 5) x6 stays exact; sparse (2, 6) x1 merges at depth>=8
    mats = [np.zeros((2, 5), np.float32)] * 6 + \
           [np.zeros((2, 6), np.float32)] * 2
    plans = plan_buckets(_reqs(mats), pol)
    assert sorted(p.shape for p in plans) == [(2, 5), (2, 8)]
    # same snapshot below merge_depth: nothing merges
    plans = plan_buckets(_reqs(mats[:4]), pol)
    assert all(not p.merged for p in plans)


def test_plan_buckets_empty_and_capacity_pinning():
    pol = BucketPolicy(max_batch=8, mode="never")
    assert plan_buckets([], pol) == []
    assert pol.capacity(0) == 0
    pinned = BucketPolicy(max_batch=8, mode="never", pin_capacity=True)
    plans = plan_buckets(_reqs([np.zeros((2, 5), np.float32)]), pinned)
    assert [p.capacity for p in plans] == [8]


def test_policy_validation():
    with pytest.raises(ValueError):
        BucketPolicy(mode="sometimes")
    with pytest.raises(ValueError):
        BucketPolicy(max_batch=0)


# ----------------------------------------------------- concurrent producers
@pytest.mark.parametrize("mode", ["never", "merge"])
def test_producer_threads_bit_identical(mode):
    """N producers submit shuffled heterogeneous matrices; every result
    comes back matched to its request and bit-identical to the
    single-threaded batched reference — under forced merges too."""
    pol = BucketPolicy(max_batch=CAP, mode=mode, pin_capacity=True)
    collected: dict[int, list] = {}
    with DetQueue(chunk=CHUNK, policy=pol) as q:
        def producer(pid):
            prng = np.random.default_rng(1000 + pid)
            mats = _mats(prng, 15)
            futs = [q.submit(A) for A in mats]  # trickled, not batched
            collected[pid] = [(A, f) for A, f in zip(mats, futs)]

        threads = [threading.Thread(target=producer, args=(pid,))
                   for pid in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        results = {pid: [(A, f.result(timeout=120)) for A, f in pairs]
                   for pid, pairs in collected.items()}
        stats = q.snapshot()
    assert stats["completed"] == stats["submitted"] == 60
    if mode == "merge":
        assert stats["merged_requests"] > 0  # forced merges actually ran
    for pid, pairs in results.items():
        for A, val in pairs:
            shape = pol.canonical_shape(*A.shape) if mode == "merge" \
                else tuple(A.shape)
            assert val == _ref(A, shape, CAP), (pid, A.shape, mode)


def test_forced_splits_bit_identical():
    """A hot bucket split across many max_batch slices by racing
    producers must not perturb a single bit."""
    pol = BucketPolicy(max_batch=4, mode="never", pin_capacity=True)
    with DetQueue(chunk=CHUNK, policy=pol) as q:
        collected: dict[int, list] = {}

        def producer(pid):
            prng = np.random.default_rng(2000 + pid)
            mats = [prng.normal(size=(2, 6)).astype(np.float32)
                    for _ in range(20)]
            collected[pid] = [(A, q.submit(A)) for A in mats]

        threads = [threading.Thread(target=producer, args=(pid,))
                   for pid in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        results = {pid: [(A, f.result(timeout=120)) for A, f in pairs]
                   for pid, pairs in collected.items()}
        stats = q.snapshot()
    assert stats["batches"] >= 10  # 40 requests / max_batch 4
    for pairs in results.values():
        for A, val in pairs:
            assert val == _ref(A, (2, 6), 4)


def test_poll_survives_close_drain_race(rng):
    """A poller blocked in poll(timeout=None) while close(drain=True)
    runs must receive every drained response before seeing end-of-stream
    (empty list) — _closing alone is not end-of-stream."""
    mats = [rng.normal(size=(3, 8)).astype(np.float32) for _ in range(24)]
    q = DetQueue(chunk=64)
    got: dict[int, float] = {}

    def poller():
        while True:
            batch = q.poll(timeout=None)
            if not batch:
                return
            got.update(batch)

    t = threading.Thread(target=poller)
    t.start()
    futs = q.submit_many(mats)
    q.close()  # drain=True: all 24 responses must still reach the poller
    t.join(timeout=120)
    assert not t.is_alive(), "poller hung after close"
    assert got == {f.seq: f.result() for f in futs}


def test_poll_responses_match_requests(rng):
    mats = _mats(rng, 12)
    with DetQueue(chunk=CHUNK,
                  policy=BucketPolicy(max_batch=CAP, mode="never")) as q:
        futs = q.submit_many(mats)
        by_seq = {}
        while len(by_seq) < len(mats):
            got = q.poll(timeout=30.0)
            assert got, "poll timed out with responses outstanding"
            by_seq.update(got)
    assert by_seq == {f.seq: f.result() for f in futs}


def test_serve_auto_policy_matches_oracle(rng):
    """Production path (dynamic policy, unpinned capacity): numerically
    tight against the exact oracle even when merges kick in."""
    mats = _mats(rng, 48)
    pol = BucketPolicy(max_batch=CAP, mode="auto", merge_depth=8)
    with DetQueue(chunk=CHUNK, policy=pol) as q:
        dets, stats = q.serve(mats, timeout=120)
    assert stats["completed"] == len(mats)
    for A, got in zip(mats, dets):
        m, n = A.shape
        want = radic_det_oracle(np.asarray(A)) if m <= n else 0.0
        assert abs(got - want) <= 2e-3 * max(1.0, abs(want))


# ------------------------------------------------------------------- edges
def test_empty_serve_dispatches_nothing():
    with DetQueue() as q:
        dets, stats = q.serve([])
    assert dets == [] and stats["batches"] == 0 and stats["dispatches"] == 0


def test_m_greater_than_n_is_zero_without_dispatch():
    with DetQueue() as q:
        fut = q.submit(np.ones((4, 2), np.float32))
        assert fut.result(timeout=60) == 0.0
        stats = q.snapshot()
    assert stats["dispatches"] == 0 and stats["batches"] == 1


def test_invalid_request_rejected_at_submit():
    with DetQueue() as q:
        with pytest.raises(ValueError):
            q.submit(np.zeros((2, 2, 2), np.float32))


def test_batch_error_fails_its_futures_and_queue_survives():
    """A per-batch failure (here: C(40, 16) overflowing int32 ranks) must
    surface on that batch's futures — not hang the caller, not kill the
    pipeline for unrelated requests."""
    with DetQueue() as q:
        bad = q.submit(np.ones((16, 40), np.float32))
        with pytest.raises(OverflowError):
            bad.result(timeout=120)
        ok = q.submit(np.ones((2, 4), np.float32))
        assert ok.result(timeout=120) == 0.0  # rank-deficient ones-matrix


def test_batch_error_reaches_poll_consumers():
    """A failed request's seq must still appear in the poll() stream
    (as the exception), or a poll-driven consumer waits forever."""
    with DetQueue() as q:
        fut = q.submit(np.ones((16, 40), np.float32))
        responses = []
        while not responses:
            responses = q.poll(timeout=30.0)
    (seq, err), = responses
    assert seq == fut.seq and isinstance(err, OverflowError)


def test_max_batch_policy_conflict_rejected():
    with pytest.raises(ValueError):
        DetQueue(max_batch=8, policy=BucketPolicy(max_batch=64))
    # agreeing values are fine
    DetQueue(max_batch=8, policy=BucketPolicy(max_batch=8)).close()


def test_admission_control_sheds_deterministically(rng):
    """submit_many is atomic under the stager's lock, so with a bound of
    4 a 10-request burst accepts exactly the first 4 and sheds the other
    6: LoadShedError on their futures, their seqs still in the poll
    stream (exactly-once), and the shed/backlog counters match."""
    mats = [rng.normal(size=(2, 5)).astype(np.float32) for _ in range(10)]
    with DetQueue(chunk=CHUNK, max_pending=4) as q:
        futs = q.submit_many(mats)
        served = [f for f in futs if not isinstance(f.exception(timeout=60),
                                                    LoadShedError)]
        shed = [f for f in futs if isinstance(f.exception(timeout=0),
                                              LoadShedError)]
        assert len(served) == 4 and len(shed) == 6
        assert [f.seq for f in served] == [0, 1, 2, 3]  # FIFO admission
        by_seq = {}
        while len(by_seq) < 10:
            got = q.poll(timeout=30.0)
            assert got, "poll timed out with responses outstanding"
            by_seq.update(got)
        stats = q.snapshot()
    assert stats["shed"] == 6 and stats["submitted"] == 10
    assert stats["completed"] == 4 and stats["backlog_peak"] == 4
    for f in served:  # shed neighbors never perturb served results
        assert f.result() == _ref(mats[f.seq], (2, 5), len(served))
    for f in shed:
        assert isinstance(by_seq[f.seq], LoadShedError)


def test_admission_recovers_after_drain(rng):
    """Shedding is not sticky: once the backlog drains, new submissions
    are admitted again."""
    A = rng.normal(size=(2, 5)).astype(np.float32)
    pol = BucketPolicy(max_batch=CAP, pin_capacity=True)  # one program shape
    with DetQueue(chunk=CHUNK, max_pending=2, policy=pol) as q:
        first = q.submit_many([A] * 5)  # 2 admitted, 3 shed
        for f in first[:2]:
            f.result(timeout=60)
        later = q.submit(A)
        assert later.result(timeout=60) == first[0].result()
        stats = q.snapshot()
    assert stats["shed"] == 3 and stats["completed"] == 3


def test_unbounded_queue_never_sheds(rng):
    mats = [rng.normal(size=(2, 5)).astype(np.float32) for _ in range(32)]
    with DetQueue(chunk=CHUNK) as q:  # max_pending=None
        dets, stats = q.serve(mats, timeout=120)
    assert stats["shed"] == 0 and stats["completed"] == 32


def test_max_pending_validation():
    with pytest.raises(ValueError):
        DetQueue(max_pending=0)


def test_plan_cache_bounded_under_long_tail_shapes(rng):
    """A queue serving more (shape, capacity) combinations than its plan
    cache holds must stay bounded — evicted shapes re-plan and still
    serve correct results (the engine's LRU contract)."""
    shapes = [(1, 4), (1, 5), (2, 5), (2, 6), (3, 7), (3, 8)]
    mats = [rng.normal(size=s).astype(np.float32) for s in shapes] * 2
    engine = DetEngine(max_plans=2)
    with DetQueue(chunk=CHUNK, engine=engine,
                  policy=BucketPolicy(max_batch=CAP, mode="never")) as q:
        dets, stats = q.serve(mats, timeout=120)
    info = stats["plan_cache"]
    assert info["max_plans"] == 2 and info["size"] <= 2
    assert info["evictions"] > 0
    for A, got in zip(mats, dets):
        want = radic_det_oracle(np.asarray(A))
        assert abs(got - want) <= 2e-3 * max(1.0, abs(want))


def test_queue_owns_bounded_engine_by_default():
    with DetQueue(plan_cache=7) as q:
        info = q.snapshot()["plan_cache"]
    assert info["max_plans"] == 7


def test_submit_after_close_raises():
    q = DetQueue()
    fut = q.submit(np.ones((1, 3), np.float32))
    q.close()
    assert fut.done()  # close(drain=True) completed the pending request
    with pytest.raises(QueueClosedError):
        q.submit(np.ones((1, 3), np.float32))
    q.close()  # idempotent


def test_close_without_drain_resolves_backlog_with_queue_closed(rng):
    """The front's worker-teardown contract: close(drain=False) with a
    non-empty backlog resolves every un-staged future with
    QueueClosedError and delivers the seqs on the poll stream — pending
    work never hangs, and callers can tell "queue went away" apart from
    a result or an evaluation error."""
    # linger_s keeps the stager parked after the atomic submit_many wake,
    # so the backlog is deterministically still un-staged at close time
    q = DetQueue(chunk=CHUNK, linger_s=30.0)
    futs = q.submit_many(
        [rng.normal(size=(3, 8)).astype(np.float32) for _ in range(4)])
    q.close(drain=False)
    for f in futs:
        assert isinstance(f.exception(timeout=60), QueueClosedError)
    got = dict(q.poll(timeout=0))
    assert set(got) == {f.seq for f in futs}
    assert all(isinstance(v, QueueClosedError) for v in got.values())
    q.close(drain=False)  # idempotent on an already-torn-down queue
    assert not any(t.is_alive() for t in q._threads)


def test_drain_pending_hands_ownership_to_caller(rng):
    """drain_pending() atomically removes the un-staged backlog and
    returns it with futures unresolved — the re-routing hook: the caller
    re-submits the arrays (here: to a second queue) and wires the
    results through, exactly what the front's retire path does."""
    mats = [rng.normal(size=(2, 6)).astype(np.float32) for _ in range(3)]
    q = DetQueue(chunk=CHUNK, linger_s=30.0)
    futs = q.submit_many(mats)
    pend = q.drain_pending()
    assert sorted(r.seq for r in pend) == [f.seq for f in futs]
    assert not any(f.done() for f in futs)
    q.close()  # backlog already drained: nothing to serve, nothing hangs
    with DetQueue(chunk=CHUNK) as q2:
        redone = q2.submit_many([r.array for r in pend])
        for r, f2 in zip(pend, redone):
            r.future.set_result(f2.result(timeout=120))
    for A, f in zip(mats, futs):
        assert f.result(timeout=0) == _ref(A, A.shape, len(mats))
