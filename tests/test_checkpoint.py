"""Battery for the checkpoint substrate and the durable plan store.

Three latent ``CheckpointManager`` bugs are pinned here with regression
tests that fail on the pre-fix code:

* ``restore`` onto a mismatched tree used a bare ``assert`` (vanishes
  under ``python -O``) and never looked at shapes or dtypes — a
  transposed leaf restored silently.  Now a typed
  :class:`CheckpointMismatchError` covers names, shapes and dtypes.
* a save that crashed between ``np.savez`` and ``os.replace`` left its
  ``.tmp-`` dir behind forever (the gc pass only matches finalized
  tags).  Init now sweeps stale tmp dirs.
* ``_gc`` kept the lexically-last ``keep`` step dirs, but LATEST points
  at the most *recently written* tag — an out-of-order lower-step save
  after a higher step could have its target deleted out from under the
  pointer.

The :class:`PlanStore` half (DESIGN_PERSIST.md) reuses the same
atomicity discipline for compiled-plan artifacts; its tests pin the
env/schema invalidation rules and the store→engine warm-start path,
including bit-identity of a store-restored AOT executable against the
freshly compiled one.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, CheckpointMismatchError,
                              PlanStore, sweep_stale_tmp)
from repro.core.engine import DetEngine, plan_statics

REPO = Path(__file__).resolve().parents[1]


# -------------------------------------------------- restore validation (fix 1)
def test_restore_name_mismatch_is_typed_error(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(1, {"w": jnp.ones((2, 3)), "b": jnp.zeros((3,))})
    with pytest.raises(CheckpointMismatchError):
        m.restore({"w": jnp.ones((2, 3)), "bias": jnp.zeros((3,))})


def test_restore_shape_mismatch_is_typed_error(tmp_path):
    """The transposed-leaf corruption: names agree, shapes do not —
    this restored silently before the fix."""
    m = CheckpointManager(str(tmp_path))
    m.save(1, {"w": jnp.arange(6.0).reshape(2, 3)})
    with pytest.raises(CheckpointMismatchError, match="shape"):
        m.restore({"w": jnp.zeros((3, 2))})


def test_restore_dtype_mismatch_is_typed_error(tmp_path):
    m = CheckpointManager(str(tmp_path))
    m.save(1, {"w": jnp.ones((4,), jnp.float32)})
    with pytest.raises(CheckpointMismatchError, match="dtype"):
        m.restore({"w": jnp.ones((4,), jnp.int32)})


def test_restore_skips_bare_python_leaves(tmp_path):
    """Leaves without shape/dtype (plain python scalars) have nothing to
    validate and must not trip the check."""
    m = CheckpointManager(str(tmp_path))
    m.save(1, {"w": jnp.ones((2,)), "step": 7})
    step, out = m.restore({"w": jnp.zeros((2,)), "step": 0})
    assert step == 1
    assert int(np.asarray(out["step"])) == 7


# -------------------------------------------------- crash atomicity (fix 2)
def test_crash_between_savez_and_replace_is_swept(tmp_path, monkeypatch):
    """Kill the save between ``np.savez`` and ``os.replace``: the
    published state must be untouched and the leftover ``.tmp-`` dir
    must be swept by the next manager init (pre-fix it accumulated
    forever)."""
    import repro.checkpoint.manager as mgr_mod
    m = CheckpointManager(str(tmp_path))
    m.save(1, {"w": jnp.ones((2,))})

    real_replace = os.replace

    def crash_replace(src, dst):
        raise OSError("simulated crash before publish")

    monkeypatch.setattr(mgr_mod.os, "replace", crash_replace)
    with pytest.raises(OSError, match="simulated crash"):
        m.save(2, {"w": jnp.full((2,), 2.0)})
    monkeypatch.setattr(mgr_mod.os, "replace", real_replace)

    # the failed write left its tmp dir (npz already written) but the
    # published checkpoint and LATEST are untouched
    leftovers = [d for d in os.listdir(tmp_path) if d.startswith(".tmp-")]
    assert leftovers == [".tmp-step_00000002"]
    assert os.path.exists(os.path.join(tmp_path, ".tmp-step_00000002",
                                       "host_0.npz"))
    assert m.latest_step() == 1

    m2 = CheckpointManager(str(tmp_path))
    assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp-")]
    step, out = m2.restore({"w": jnp.zeros((2,))})
    assert step == 1
    np.testing.assert_array_equal(np.asarray(out["w"]), np.ones((2,)))


def test_sweep_stale_tmp_reports_and_tolerates_missing_dir(tmp_path):
    os.makedirs(os.path.join(tmp_path, ".tmp-step_00000009"))
    assert sweep_stale_tmp(str(tmp_path)) == [".tmp-step_00000009"]
    assert sweep_stale_tmp(str(tmp_path / "nope")) == []


# ------------------------------------------------------ gc vs LATEST (fix 3)
def test_gc_never_deletes_latest_target_out_of_order(tmp_path):
    """A lower-step save landing after a higher step (restart from an
    older checkpoint) makes LATEST point at a lexically-early dir; with
    a small keep the pre-fix gc deleted that dir out from under the
    pointer."""
    m = CheckpointManager(str(tmp_path), keep=1)
    m.save(5, {"w": jnp.full((2,), 5.0)})
    m.save(3, {"w": jnp.full((2,), 3.0)})  # out-of-order: LATEST -> step 3
    assert m.latest_step() == 3
    assert os.path.isdir(os.path.join(tmp_path, "step_00000003"))
    step, out = m.restore({"w": jnp.zeros((2,))})
    assert step == 3
    np.testing.assert_array_equal(np.asarray(out["w"]), np.full((2,), 3.0))
    # a subsequent in-order save moves LATEST forward and gc resumes
    m.save(6, {"w": jnp.full((2,), 6.0)})
    assert m.latest_step() == 6


# ------------------------------------------------------------- battery: core
def test_save_restore_bit_identity_plan_meta_tree(tmp_path):
    """A grad-plan-shaped metadata tree (int32 rank table + float params
    + scalars) round-trips bit-identically, dtypes included."""
    total, table, chunk = plan_statics(3, 7, 128)
    tree = {"table": np.asarray(table),
            "weights": np.linspace(-1, 1, 12, dtype=np.float32).reshape(3, 4),
            "meta": {"total": np.int32(total), "chunk": np.int32(chunk)}}
    m = CheckpointManager(str(tmp_path))
    m.save(11, tree)
    step, out = m.restore(tree)
    assert step == 11
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(a, b)


def test_save_async_overlaps_with_blocking_save(tmp_path):
    """An async save still in flight must serialize with the next
    blocking save (never two writers in one tmp dir), and both steps
    stay restorable."""
    m = CheckpointManager(str(tmp_path))
    m.save_async(5, {"w": jnp.full((64, 64), 5.0)})
    m.save(6, {"w": jnp.full((64, 64), 6.0)})
    m.wait()
    assert m.latest_step() == 6
    for step, val in ((5, 5.0), (6, 6.0)):
        got, out = m.restore({"w": jnp.zeros((64, 64))}, step=step)
        assert got == step
        assert float(np.asarray(out["w"])[0, 0]) == val


def test_elastic_restore_across_device_counts(tmp_path):
    """A checkpoint written from a 2-device host restores onto this
    process's single device: the manifest stores only the logical tree,
    so device count is a restore-time choice."""
    script = (
        "import numpy as np, jax\n"
        "from jax.sharding import Mesh, NamedSharding, PartitionSpec as P\n"
        "from repro.checkpoint import CheckpointManager\n"
        "devs = jax.devices()\n"
        "assert len(devs) == 2, devs\n"
        "mesh = Mesh(np.array(devs), ('d',))\n"
        "x = jax.device_put(jax.numpy.arange(8.0).reshape(4, 2),\n"
        "                   NamedSharding(mesh, P('d', None)))\n"
        f"CheckpointManager({str(tmp_path)!r}).save(3, {{'w': x}})\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=2").strip()
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=300, cwd=REPO, env=env)
    assert r.returncode == 0, r.stderr
    m = CheckpointManager(str(tmp_path))
    step, out = m.restore({"w": jnp.zeros((4, 2))})
    assert step == 3
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.arange(8.0).reshape(4, 2))


# -------------------------------------------------------------- plan store
def test_plan_store_roundtrip_atomic(tmp_path):
    s = PlanStore(str(tmp_path), env={"jax": "x", "backend": "cpu"})
    s.put(0xABC, {"key": {"m": 2, "n": 5}}, {"fwd": b"\x00\x01bytes"})
    meta, blobs = s.get(0xABC)
    assert meta == {"key": {"m": 2, "n": 5}}
    assert blobs == {"fwd": b"\x00\x01bytes"}
    assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp-")]
    assert s.get(0xDEF) is None
    assert s.families() == [{"key": {"m": 2, "n": 5}}]
    assert s.stats()["entries"] == 1


def test_plan_store_env_and_schema_invalidation(tmp_path):
    """The invalidation rules (DESIGN_PERSIST.md): a manifest written
    under another env stamp or schema version is a miss — never an
    error, never a cross-version restore."""
    a = PlanStore(str(tmp_path), env={"jax": "0.4", "backend": "cpu"})
    a.put(1, {"key": {"m": 1, "n": 1}}, {"fwd": b"z"})
    b = PlanStore(str(tmp_path), env={"jax": "0.5", "backend": "cpu"})
    assert b.get(1) is None and b.families() == []
    assert a.get(1) is not None  # matching env still hits
    # schema bump: rewrite the manifest with a foreign version
    entry = os.path.join(tmp_path, PlanStore.entry_name(1))
    with open(os.path.join(entry, "manifest.json")) as f:
        manifest = json.load(f)
    manifest["schema"] = 99
    with open(os.path.join(entry, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    assert a.get(1) is None and a.families() == []


def test_plan_store_deferred_blobs_and_flush(tmp_path):
    """Blob values may be zero-arg callables (evaluated on the writer
    thread); a callable returning None means the serializer declined —
    the entry is published metadata-only."""
    s = PlanStore(str(tmp_path))
    s.put_async(7, {"key": {"m": 3, "n": 7}},
                {"fwd": lambda: b"exported", "grad": lambda: None})
    s.flush()
    meta, blobs = s.get(7)
    assert blobs == {"fwd": b"exported"}
    stats = s.stats()
    assert stats["written"] == 1 and stats["pending"] == 0
    s.close()


def test_plan_store_sweeps_stale_tmp_and_missing_blob_is_miss(tmp_path):
    os.makedirs(os.path.join(tmp_path, ".tmp-plan_crashed"))
    s = PlanStore(str(tmp_path))
    assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp-")]
    s.put(9, {"key": {}}, {"fwd": b"x"})
    os.remove(os.path.join(tmp_path, PlanStore.entry_name(9), "fwd.bin"))
    assert s.get(9) is None  # manifest promises a blob that is gone


# ------------------------------------------------- engine store warm start
def test_engine_store_warm_start_bit_identical(tmp_path, rng):
    """An engine restarted onto a populated store restores the plan
    (store hit) and produces bit-identical batched results — the same
    invariant the serving tier's warm-start rides on."""
    As = jnp.asarray(rng.normal(size=(4, 2, 5)).astype(np.float32))
    e1 = DetEngine(persist_dir=str(tmp_path))
    p1 = e1.plan(2, 5, batched=True, capacity=4, chunk=128)
    want = np.asarray(jax.block_until_ready(p1(As)))
    e1.flush_store()
    info1 = e1.cache_info()
    assert info1["store_misses"] == 1 and info1["store_hits"] == 0
    assert e1.store.stats()["entries"] == 1

    e2 = DetEngine(persist_dir=str(tmp_path))
    p2 = e2.plan(2, 5, batched=True, capacity=4, chunk=128)
    info2 = e2.cache_info()
    assert info2["store_hits"] == 1 and info2["store_misses"] == 0
    got = np.asarray(jax.block_until_ready(p2(As)))
    np.testing.assert_array_equal(got, want)  # bit identity, no tolerance


def test_engine_prefill_from_store(tmp_path):
    e1 = DetEngine(persist_dir=str(tmp_path))
    e1.plan(2, 5, batched=True, capacity=4, chunk=128)
    e1.flush_store()

    e3 = DetEngine(persist_dir=str(tmp_path))
    assert e3.prefill() == 1
    info = e3.cache_info()
    assert info["size"] == 1 and info["store_hits"] == 1
    # the prefilled family is a plain cache hit for real traffic
    e3.plan(2, 5, batched=True, capacity=4, chunk=128)
    info = e3.cache_info()
    assert info["hits"] == 1 and info["misses"] == 1


def test_engine_without_store_unchanged(tmp_path):
    e = DetEngine()
    e.plan(2, 5, batched=True, capacity=4, chunk=128)
    info = e.cache_info()
    assert info["store_hits"] == info["store_misses"] == 0
    assert e.store is None
    e.flush_store()  # no-op, must not raise
    assert e.prefill() == 0


# ------------------------------------------------- export seam + XLA cache


def test_export_seam_blobs_default_off(monkeypatch):
    # Blob reload segfaults on jax legs whose serialized executables bake
    # in native custom-call pointers (every LAPACK-backed det program), so
    # the seam must refuse blobs unless the environment opts in — see the
    # compat export seam / DESIGN_PERSIST.md invalidation rules.
    from repro.parallel import compat

    monkeypatch.delenv("REPRO_PLAN_BLOBS", raising=False)
    assert compat.export_supported() is False
    fn = jax.jit(lambda x: x + 1.0)
    assert compat.serialize_lowered(fn, jnp.ones((2,), jnp.float32)) is None
    assert compat.deserialize_exported(b"\x00" * 8) is None


def test_export_seam_opt_in_round_trip(monkeypatch):
    from repro.parallel import compat

    monkeypatch.setenv("REPRO_PLAN_BLOBS", "1")
    if not compat.export_supported():
        pytest.skip("jax.export unavailable on this jax leg")
    x = jnp.arange(6.0, dtype=jnp.float32)
    blob = compat.serialize_lowered(jax.jit(lambda v: v * 3.0), x)
    assert isinstance(blob, bytes) and blob
    # custom-call-free programs reload safely on every supported leg
    fn = compat.deserialize_exported(blob)
    assert fn is not None
    np.testing.assert_array_equal(
        np.asarray(jax.block_until_ready(fn(x))), np.asarray(x) * 3.0)
    # garbage still degrades to None, never raises
    assert compat.deserialize_exported(b"not a blob") is None


def test_store_houses_xla_compilation_cache(tmp_path):
    # Metadata-only records re-lower at warm-up; the compile itself is
    # skipped via the XLA persistent compilation cache the store points
    # jax at.  The config is process-global and latched at first
    # compile, so prove it end to end in a fresh interpreter.
    script = """
import os, sys
import jax
from repro.core.engine import DetEngine

store = sys.argv[1]
e = DetEngine(persist_dir=store)
assert jax.config.jax_compilation_cache_dir == os.path.join(
    store, "xla-cache"), jax.config.jax_compilation_cache_dir
e.plan(2, 5, batched=True, capacity=4, chunk=64)
e.flush_store()
cache = os.path.join(store, "xla-cache")
entries = [f for f in os.listdir(cache) if f.endswith("-cache")]
assert entries, "no compiled executables landed in the cache"
print(len(entries))
"""
    out = subprocess.run(
        [sys.executable, "-c", script, str(tmp_path)],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": str(REPO / "src")})
    assert out.returncode == 0, out.stderr
    assert int(out.stdout.strip()) >= 1


def test_enable_compilation_cache_defers_to_user_config(tmp_path):
    # An explicitly configured cache dir must win over the store's.
    script = """
import os, sys
import jax
jax.config.update("jax_compilation_cache_dir", sys.argv[2])
from repro.parallel import compat

assert compat.enable_compilation_cache(sys.argv[1]) is True
assert jax.config.jax_compilation_cache_dir == sys.argv[2]
print("ok")
"""
    out = subprocess.run(
        [sys.executable, "-c", script, str(tmp_path / "store-cache"),
         str(tmp_path / "user-cache")],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": str(REPO / "src")})
    assert out.returncode == 0, out.stderr
    assert out.stdout.strip() == "ok"
