"""Seeded-random fallback for the slice of the hypothesis API this repo's
property tests use, so tier-1 runs on boxes without hypothesis installed.

Not a shrinker and not a coverage-guided fuzzer — just deterministic
seeded sampling of the same strategies: each ``@given`` test body runs
``MAX_EXAMPLES`` times with draws from a per-example ``numpy`` Generator
seeded by the example index, so failures reproduce exactly across runs.

Import it the way the test modules do::

    try:
        from hypothesis import given, settings, strategies as st
    except ModuleNotFoundError:
        from _hyp_fallback import given, settings, st
"""

from __future__ import annotations

import numpy as np

MAX_EXAMPLES = 25
_FILTER_TRIES = 1000


class _Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def draw(self, rng):
        return self._draw(rng)

    def filter(self, pred):
        def draw(rng):
            for _ in range(_FILTER_TRIES):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise RuntimeError("filter predicate rejected too many draws")
        return _Strategy(draw)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)))

    def flatmap(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)).draw(rng))


class _DataMarker:
    """Sentinel strategy standing in for ``st.data()``."""


class _DataObject:
    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy.draw(self._rng)


class _Strategies:
    """Namespace mirroring ``hypothesis.strategies`` (the used subset)."""

    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value, allow_nan=None, allow_infinity=None):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def just(value):
        return _Strategy(lambda rng: value)

    @staticmethod
    def tuples(*strategies):
        return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            size = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(size)]
        return _Strategy(draw)

    @staticmethod
    def data():
        return _DataMarker()


st = _Strategies()


def given(*strategies):
    """Run the test body over MAX_EXAMPLES deterministic seeded draws."""
    def deco(fn):
        def wrapper():
            for example in range(MAX_EXAMPLES):
                rng = np.random.default_rng(0x5EED + 9973 * example)
                args = [_DataObject(rng) if isinstance(s, _DataMarker)
                        else s.draw(rng) for s in strategies]
                try:
                    fn(*args)
                except Exception as e:
                    raise AssertionError(
                        f"falsified on example {example}: "
                        f"args={args!r}") from e
        # plain __name__ copy, NOT functools.wraps: pytest must see a
        # zero-arg signature, not the strategy parameters (it would try
        # to resolve them as fixtures)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


def settings(*args, **kwargs):
    """No-op stand-in for ``hypothesis.settings`` used as a decorator."""
    def deco(fn):
        return fn
    return deco
