"""Property tests for the Radic determinant (properties from Radic [12])."""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # optional extra — seeded-random fallback
    from _hyp_fallback import given, settings, st

from repro.core import radic_det, radic_det_exact, radic_det_oracle
from repro.core.pascal import binom_table, comb

dims = st.tuples(st.integers(1, 4), st.integers(1, 8)).filter(
    lambda t: t[0] <= t[1])


def _mat(rng_seed, m, n):
    return np.random.default_rng(rng_seed).normal(
        size=(m, n)).astype(np.float32)


@given(dims, st.integers(0, 2**31 - 1))
def test_matches_oracle(dims, seed):
    m, n = dims
    A = _mat(seed, m, n)
    got = float(radic_det(jnp.asarray(A), chunk=64))
    want = radic_det_oracle(A)
    assert abs(got - want) <= 1e-3 * max(1.0, abs(want))


@given(st.integers(1, 5), st.integers(0, 2**31 - 1))
def test_square_case_is_standard_det(m, seed):
    """m == n: Radic's definition reduces to the classical determinant."""
    A = _mat(seed, m, m)
    got = float(radic_det(jnp.asarray(A)))
    assert abs(got - np.linalg.det(A)) <= 1e-3 * max(1, abs(np.linalg.det(A)))


@given(dims.filter(lambda t: t[0] >= 2), st.integers(0, 2**31 - 1))
def test_equal_rows_give_zero(dims, seed):
    m, n = dims
    A = _mat(seed, m, n)
    A[m - 1] = A[0]  # duplicate a row -> every minor is singular
    got = float(radic_det(jnp.asarray(A), chunk=64))
    assert abs(got) <= 1e-3


@given(dims, st.integers(0, 2**31 - 1),
       st.floats(-3, 3, allow_nan=False).filter(lambda a: abs(a) > 1e-2))
def test_row_scaling_linearity(dims, seed, alpha):
    m, n = dims
    A = _mat(seed, m, n)
    B = A.copy()
    B[0] *= alpha
    d_a = float(radic_det(jnp.asarray(A), chunk=64))
    d_b = float(radic_det(jnp.asarray(B), chunk=64))
    assert abs(d_b - alpha * d_a) <= 1e-2 * max(1.0, abs(alpha * d_a))


@given(dims.filter(lambda t: t[0] >= 2), st.integers(0, 2**31 - 1))
def test_row_swap_negates(dims, seed):
    m, n = dims
    A = _mat(seed, m, n)
    B = A.copy()
    B[[0, 1]] = B[[1, 0]]
    d_a = float(radic_det(jnp.asarray(A), chunk=64))
    d_b = float(radic_det(jnp.asarray(B), chunk=64))
    assert abs(d_a + d_b) <= 1e-3 * max(1.0, abs(d_a))


@given(dims.filter(lambda t: t[0] >= 2), st.integers(0, 2**31 - 1))
def test_row_elimination_invariance(dims, seed):
    """Adding a multiple of one row to another preserves det (per minor)."""
    m, n = dims
    A = _mat(seed, m, n)
    B = A.copy()
    B[1] += 0.5 * B[0]
    d_a = float(radic_det(jnp.asarray(A), chunk=64))
    d_b = float(radic_det(jnp.asarray(B), chunk=64))
    assert abs(d_a - d_b) <= 2e-3 * max(1.0, abs(d_a))


def test_m_equals_1_alternating_sum():
    """m=1: det = Σ_j (−1)^(1+j) a_1j (r=1, s=j)."""
    a = np.array([[1.0, 2.0, 3.0, 4.0]], dtype=np.float32)
    want = 1 - 2 + 3 - 4
    assert abs(float(radic_det(jnp.asarray(a))) - want) < 1e-5


def test_m_greater_than_n_is_zero():
    A = np.ones((4, 3), np.float32)
    assert float(radic_det(jnp.asarray(A))) == 0.0


@settings(max_examples=10)
@given(st.integers(1, 3), st.integers(1, 6), st.integers(0, 2**31 - 1))
def test_exact_integer_agreement(m, n, seed):
    """Float path vs exact Bareiss/Fraction oracle on integer matrices."""
    if m > n:
        m, n = n, m
    A = np.random.default_rng(seed).integers(-4, 5, size=(m, n))
    got = float(radic_det(jnp.asarray(A.astype(np.float32)), chunk=64))
    want = float(radic_det_exact(A))
    assert abs(got - want) <= 1e-3 * max(1.0, abs(want))


def test_binom_table_guard_uses_true_table_peak():
    """m > n/2 regression: C(40,30)=C(40,10) fits int32, but the table
    stores the mid-column C(40,20) ≈ 1.4e11, which must raise — not
    silently wrap — for an int32 table."""
    assert comb(40, 30) < 2**31 - 1 < comb(40, 20)
    with pytest.raises(OverflowError):
        binom_table(40, 30, dtype=np.int32)
    T = binom_table(40, 30, dtype=np.int64)  # int64 holds the peak
    assert T[40, 20] == comb(40, 20)
    assert T[40, 30] == comb(40, 30)
    # m <= n/2 unaffected: peak is C(n, m) itself
    T32 = binom_table(40, 10, dtype=np.int32)
    assert T32[40, 10] == comb(40, 10)


def test_kahan_matches_plain():
    A = np.random.default_rng(7).normal(size=(4, 10)).astype(np.float32)
    plain = float(radic_det(jnp.asarray(A), chunk=32))
    kahan = float(radic_det(jnp.asarray(A), chunk=32, kahan=True))
    want = radic_det_oracle(A)
    assert abs(kahan - want) <= abs(plain - want) + 1e-4
