"""Distributed Radic determinant: grains/flat modes, multi-device via a
subprocess with forced host platform device count (the only place tests
use >1 device)."""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plan_grains, radic_det_distributed, radic_det_oracle


def test_plan_grains_partitions_exactly():
    for total in [1, 7, 56, 1000]:
        for g in [1, 3, 8]:
            starts, lengths = plan_grains(total, g)
            assert starts[0] == 0
            assert sum(lengths) == total
            assert all(l >= 0 for l in lengths)
            for s, l, s2 in zip(starts, lengths, starts[1:] + [total]):
                assert s + l == s2


@pytest.mark.parametrize("mode,kw", [
    ("grains", dict(grains_per_device=1)),
    ("grains", dict(grains_per_device=4)),
    ("flat", dict(chunk=16)),
    ("flat", dict(chunk=16, backend="pallas")),
])
def test_single_device_modes(mode, kw, rng):
    A = rng.normal(size=(3, 8)).astype(np.float32)
    got = float(radic_det_distributed(jnp.asarray(A), mode=mode, **kw))
    want = radic_det_oracle(A)
    assert abs(got - want) <= 2e-3 * max(1.0, abs(want))


def test_grains_survive_uneven_split(rng):
    """56 subsets over 5 grains -> uneven lengths; reduction must be exact."""
    A = rng.normal(size=(5, 8)).astype(np.float32)
    got = float(radic_det_distributed(jnp.asarray(A), grains_per_device=5))
    want = radic_det_oracle(A)
    assert abs(got - want) <= 2e-3 * max(1.0, abs(want))


MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core import radic_det_distributed, radic_det_oracle
    assert len(jax.devices()) == 8
    rng = np.random.default_rng(3)
    A = rng.normal(size=(4, 10)).astype(np.float32)
    want = radic_det_oracle(A)
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    for kw in (dict(mode="grains", grains_per_device=2),
               dict(mode="grains", grains_per_device=1),
               dict(mode="flat", chunk=32),
               dict(mode="flat", chunk=32, backend="pallas")):
        got = float(radic_det_distributed(jnp.asarray(A), mesh=mesh, **kw))
        assert abs(got - want) <= 2e-3 * max(1.0, abs(want)), (kw, got, want)
    print("MULTIDEV_OK")
""")


def test_eight_device_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "MULTIDEV_OK" in out.stdout, (out.stdout, out.stderr[-2000:])
