"""Backend parity: the fused Pallas batched kernel and the jnp flat path
must agree on identical inputs, across dtypes and the bucket capacities
the serving tier dispatches (1, 2, max_batch).

The kernel computes in float32 internally (TPU VPU/MXU), so the float64
leg — run in a subprocess with x64 enabled to keep this process's global
config untouched — asserts parity at float32 precision while checking
the jnp path really produced float64.
"""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_batched_evaluator, radic_det_batched

REPO = os.path.dirname(os.path.dirname(__file__))

MAX_BATCH = 8  # the bucket capacity this battery serves at
CAPACITIES = (1, 2, MAX_BATCH)
SHAPES = [(2, 6), (3, 7), (1, 5), (3, 3), (4, 9)]


@pytest.mark.parametrize("cap", CAPACITIES)
@pytest.mark.parametrize("m,n", SHAPES)
def test_backends_agree_float32(m, n, cap, rng):
    As = jnp.asarray(rng.normal(size=(cap, m, n)).astype(np.float32))
    got_pallas = np.asarray(radic_det_batched(As, backend="pallas"))
    got_jnp = np.asarray(radic_det_batched(As, chunk=64))
    assert got_pallas.shape == got_jnp.shape == (cap,)
    np.testing.assert_allclose(got_pallas, got_jnp, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("cap", CAPACITIES)
def test_evaluator_backends_agree(cap, rng):
    """The bound-shape evaluators (DetQueue's dispatch path) agree the
    same way the one-shot entry points do."""
    m, n = 3, 8
    As = jnp.asarray(rng.normal(size=(cap, m, n)).astype(np.float32))
    ev_jnp = make_batched_evaluator(m, n, chunk=64)
    ev_pal = make_batched_evaluator(m, n, backend="pallas")
    np.testing.assert_allclose(np.asarray(ev_pal(As)), np.asarray(ev_jnp(As)),
                               rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("cap", CAPACITIES)
@pytest.mark.parametrize("m,n", SHAPES)
def test_combo_kernel_bit_identical_to_bygrid(m, n, cap, rng):
    """The combo-reuse batched kernel (tile-only grid, batch contracted
    in-kernel) must be *bit-identical* to the legacy (B, num_tiles)
    grid: per-lane math is unchanged, only the sharing of the unranked
    tile differs.  Exact equality, not allclose — any reassociation of
    the per-matrix reduce would break the serving tier's bit-identity
    story."""
    from repro.kernels import ops
    As = jnp.asarray(rng.normal(size=(cap, m, n)).astype(np.float32))
    combo = np.asarray(ops.radic_det_batched_pallas(As))
    bygrid = np.asarray(ops.radic_det_batched_pallas_bygrid(As))
    np.testing.assert_array_equal(combo, bygrid)


def test_combo_kernel_bit_identical_partial_ranges(rng):
    """Rank-range partials (the distributed grain path) stay bit-identical
    too, including a range that straddles a tile boundary."""
    from repro.kernels import ops
    m, n = 3, 9  # C(9, 3) = 84
    As = jnp.asarray(rng.normal(size=(4, m, n)).astype(np.float32))
    for q_start, count in [(0, 84), (10, 40), (60, 24)]:
        combo = np.asarray(ops.radic_det_batched_pallas(
            As, q_start, count, tile=32))
        bygrid = np.asarray(ops.radic_det_batched_pallas_bygrid(
            As, q_start, count, tile=32))
        np.testing.assert_array_equal(combo, bygrid)


X64_PARITY = textwrap.dedent("""
    import os
    os.environ["JAX_ENABLE_X64"] = "True"
    import numpy as np, jax, jax.numpy as jnp
    assert jax.config.jax_enable_x64
    from repro.core import radic_det_batched
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    for cap in (1, 2, 8):
        for (m, n) in [(2, 6), (3, 7), (3, 3)]:
            As = jnp.asarray(rng.normal(size=(cap, m, n)))  # float64
            got_j = np.asarray(radic_det_batched(As, chunk=64))
            assert got_j.dtype == np.float64, got_j.dtype
            got_p = np.asarray(radic_det_batched(As, backend="pallas"))
            # kernel math is f32 internally: parity at f32 precision
            assert np.allclose(got_p, got_j, rtol=1e-3, atol=1e-4), \\
                (cap, m, n, got_p, got_j)
            # combo-reuse vs legacy grid stays bitwise under x64 too
            got_c = np.asarray(ops.radic_det_batched_pallas(As))
            got_g = np.asarray(ops.radic_det_batched_pallas_bygrid(As))
            assert np.array_equal(got_c, got_g), (cap, m, n, got_c, got_g)
    print("X64_PARITY_OK")
""")


def test_backends_agree_float64_when_enabled():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", X64_PARITY],
                         capture_output=True, text=True, env=env, cwd=REPO)
    assert "X64_PARITY_OK" in out.stdout, (out.stdout, out.stderr[-2000:])
