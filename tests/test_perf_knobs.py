"""The §Perf optimization knobs must preserve semantics exactly."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, build_model

BASE = dict(name="t", family="dense", n_layers=2, d_model=32, n_heads=4,
            n_kv_heads=2, head_dim=8, d_ff=64, vocab_size=97,
            dtype="float32")


@pytest.fixture(scope="module")
def setup():
    m = build_model(ModelConfig(**BASE, remat=False))
    p = m.init(jax.random.PRNGKey(1))
    tok = jax.random.randint(jax.random.PRNGKey(0), (2, 12), 0, 97)
    return m, p, tok


def test_chunked_ce_matches_full(setup):
    m, p, tok = setup
    batch = {"tokens": tok, "labels": tok}
    full = float(m.loss(p, batch))
    for chunk in (1, 5, 12, 64):
        mc = build_model(ModelConfig(**BASE, remat=False,
                                     loss_chunk=chunk))
        assert abs(float(mc.loss(p, batch)) - full) < 1e-4
    g1 = jax.grad(m.loss)(p, batch)
    g2 = jax.grad(build_model(
        ModelConfig(**BASE, remat=False, loss_chunk=5)).loss)(p, batch)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=1e-5)


def test_chunked_ce_respects_label_mask(setup):
    m, p, tok = setup
    labels = tok.at[:, 3:6].set(-1)
    batch = {"tokens": tok, "labels": labels}
    full = float(m.loss(p, batch))
    mc = build_model(ModelConfig(**BASE, remat=False, loss_chunk=4))
    assert abs(float(mc.loss(p, batch)) - full) < 1e-4


def test_dus_cache_update_matches_forward(setup):
    m, p, tok = setup
    md = build_model(ModelConfig(**BASE, remat=False, cache_update="dus"))
    full, _ = m.forward(p, tok)
    lg, cache = md.prefill(p, tok[:, :9], max_len=12)
    for t in range(9, 12):
        lg, cache = md.decode_step(p, cache, tok[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, t]),
                                   rtol=2e-3, atol=2e-3)


def test_chunked_attention_matches_dense(setup):
    m, p, tok = setup
    full, _ = m.forward(p, tok)
    for chunk in (4, 5, 12, 32):
        mc = build_model(ModelConfig(**BASE, remat=False,
                                     attn_chunk=chunk))
        lc, _ = mc.forward(p, tok)
        np.testing.assert_allclose(np.asarray(full), np.asarray(lc),
                                   rtol=2e-4, atol=2e-4)


def test_chunked_attention_with_window_softcap():
    kw = dict(attn_window=4, local_global_period=2,
              attn_logit_softcap=50.0)
    m1 = build_model(ModelConfig(**BASE, remat=False, **kw))
    p = m1.init(jax.random.PRNGKey(1))
    tok = jax.random.randint(jax.random.PRNGKey(0), (2, 13), 0, 97)
    l1, _ = m1.forward(p, tok)
    m2 = build_model(ModelConfig(**BASE, remat=False, attn_chunk=4, **kw))
    l2, _ = m2.forward(p, tok)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-4, atol=2e-4)


def test_remat_policies_match_no_remat(setup):
    m, p, tok = setup
    batch = {"tokens": tok, "labels": tok}
    want = float(m.loss(p, batch))
    for pol in ("nothing", "dots"):
        mr = build_model(ModelConfig(**BASE, remat=True, remat_policy=pol))
        assert abs(float(mr.loss(p, batch)) - want) < 1e-5
        g = jax.grad(mr.loss)(p, batch)
        assert all(np.isfinite(np.asarray(x)).all()
                   for x in jax.tree.leaves(g))
