"""Property tests (hypothesis) for combinatorial addition / unranking."""

import itertools

import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, strategies as st
except ModuleNotFoundError:  # optional extra — seeded-random fallback
    from _hyp_fallback import given, st

from repro.core import (comb, rank_jnp, rank_py, successor_jnp,
                        successor_py, unrank_jnp, unrank_py)

nm = st.integers(1, 14).flatmap(
    lambda n: st.tuples(st.just(n), st.integers(1, n)))


@given(nm, st.data())
def test_rank_unrank_roundtrip(nm, data):
    n, m = nm
    q = data.draw(st.integers(0, comb(n, m) - 1))
    combo = unrank_py(q, n, m)
    assert rank_py(combo, n, m) == q
    assert len(combo) == m
    assert all(1 <= c <= n for c in combo)
    assert all(a < b for a, b in zip(combo, combo[1:]))


@given(nm, st.data())
def test_unrank_matches_itertools(nm, data):
    """Theorem 2: combinatorial addition == dictionary order."""
    n, m = nm
    q = data.draw(st.integers(0, comb(n, m) - 1))
    want = next(itertools.islice(
        itertools.combinations(range(1, n + 1), m), q, None))
    assert unrank_py(q, n, m) == want


@given(nm, st.data())
def test_jnp_matches_host(nm, data):
    n, m = nm
    qs = data.draw(st.lists(st.integers(0, comb(n, m) - 1),
                            min_size=1, max_size=16))
    got = np.asarray(unrank_jnp(jnp.asarray(qs, jnp.int32), n, m))
    want = np.array([unrank_py(q, n, m) for q in qs])
    assert (got == want).all()
    back = np.asarray(rank_jnp(jnp.asarray(got, jnp.int32), n, m))
    assert (back == np.array(qs)).all()


@given(nm, st.data())
def test_successor_chain(nm, data):
    n, m = nm
    q = data.draw(st.integers(0, comb(n, m) - 1))
    combo = unrank_py(q, n, m)
    nxt = successor_py(combo, n)
    if q == comb(n, m) - 1:
        assert nxt is None
    else:
        assert nxt == unrank_py(q + 1, n, m)
        got = np.asarray(successor_jnp(
            jnp.asarray([combo], jnp.int32), n))[0]
        assert tuple(got) == nxt


@given(nm, st.data())
def test_monotone_in_dictionary_order(nm, data):
    """q1 < q2  =>  unrank(q1) <^d unrank(q2) (Definition 2)."""
    n, m = nm
    total = comb(n, m)
    q1 = data.draw(st.integers(0, total - 1))
    q2 = data.draw(st.integers(0, total - 1))
    c1, c2 = unrank_py(q1, n, m), unrank_py(q2, n, m)
    assert (q1 < q2) == (c1 < c2)  # tuple compare == dictionary order
