"""Regression battery for the runtime substrate fixes.

Three bugs are pinned here so they cannot come back:

* ``Watchdog`` read/wrote its deadline and latch without a lock — a
  beater thread racing the monitor could see a stale deadline and fire
  spuriously, and ``fired`` latched forever with no way to clear it.
* ``StepTimer`` counted the EMA *seed* sample toward warmup, shifting
  the detection gate by one step and skewing the ids in ``stragglers``.
* ``run_grains`` mutated the shared ``fail_on`` set outside the
  scheduler lock (two speculative attempts could both consume one
  failure token) and hardcoded the attempt cap, with a terminal error
  that named nothing.
"""

import threading
import time

import pytest

from repro.runtime import StepTimer, Watchdog, run_grains


# ------------------------------------------------------------------ watchdog
def test_watchdog_quiet_under_concurrent_beating():
    """Four threads beating every 10 ms for 3× the timeout: the monitor
    must never observe a stale deadline and fire (pre-fix, the unlocked
    check-then-act raced the beaters)."""
    fired = []
    wd = Watchdog(0.5, lambda: fired.append(time.monotonic())).start()
    stop = threading.Event()

    def beater():
        while not stop.is_set():
            wd.beat()
            time.sleep(0.01)

    threads = [threading.Thread(target=beater) for _ in range(4)]
    for t in threads:
        t.start()
    time.sleep(1.5)
    stop.set()
    for t in threads:
        t.join()
    wd.stop()
    assert fired == []
    assert wd.fired is False


def test_watchdog_reset_clears_latch_and_rearms():
    fired = []
    wd = Watchdog(0.1, lambda: fired.append(1)).start()
    time.sleep(0.3)
    assert wd.fired is True and fired
    wd.reset()
    wd.beat()
    wd.stop()
    assert wd.fired is False  # one stall must not poison later probes


def test_watchdog_on_stall_may_reset_without_deadlock():
    """The stall handler runs outside the lock, so it may beat()/reset()
    the watchdog itself; a handler that deadlocked would wedge the
    monitor thread after the first fire."""
    fires = []
    holder = {}

    def handler():
        fires.append(time.monotonic())
        holder["wd"].reset()

    holder["wd"] = Watchdog(0.1, handler).start()
    deadline = time.monotonic() + 5.0
    while len(fires) < 2 and time.monotonic() < deadline:
        time.sleep(0.02)
    holder["wd"].stop()
    assert len(fires) >= 2  # kept firing => handler's reset() didn't wedge


# ----------------------------------------------------------------- steptimer
def test_step_timer_seed_is_calibration_not_warmup():
    t = StepTimer(warmup=2)
    assert t.record(1, 1.0) is False  # seeds the EMA ...
    assert t.n == 0                   # ... but is not a measured sample


def test_step_timer_warmup_gate_exact_steps():
    """Known dt sequence that distinguishes the fixed gate from the
    off-by-one: with ``warmup=2`` the seed plus two measured samples
    pass unflagged, so step 3's outlier (the 2nd measured sample) is
    still warmup — under the old seed-counted gate it was flagged.
    Step 3's dt then *feeds the EMA*, which the old gate never allowed.
    """
    t = StepTimer(warmup=2)  # alpha=0.1, factor=2.0
    dts = {1: 1.0, 2: 1.0, 3: 5.0, 4: 1.0, 5: 5.0}
    flags = [t.record(step, dts[step]) for step in sorted(dts)]
    # step 3: n=2, gate 2 > 2 is False -> absorbed: ema = .9*1 + .1*5 = 1.4
    # step 5: n=4, armed; 5.0 > 2*1.36 -> flagged (old gate: [3, 5])
    assert flags == [False, False, False, False, True]
    assert t.stragglers == [5]
    assert t.ema == pytest.approx(0.9 * 1.4 + 0.1 * 1.0)  # outlier excluded


# ---------------------------------------------------------------- run_grains
def test_run_grains_max_attempts_caps_reissue():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 4:
            raise RuntimeError("boom")
        return 7.0

    with pytest.raises(RuntimeError, match=r"max_attempts=3"):
        run_grains([flaky], 1, max_attempts=3)
    calls["n"] = 0
    assert run_grains([flaky], 1, max_attempts=4) == [7.0]


def test_run_grains_terminal_error_names_grains_and_attempts():
    def bad():
        raise RuntimeError("boom")

    with pytest.raises(RuntimeError) as ei:
        run_grains([bad, lambda: 1.0, bad], 2, max_attempts=2)
    msg = str(ei.value)
    assert "grain 0 after 2 attempt(s)" in msg
    assert "grain 2 after 2 attempt(s)" in msg
    assert "grain 1" not in msg  # the grain that finished is not blamed


def test_run_grains_validates_max_attempts():
    with pytest.raises(ValueError):
        run_grains([lambda: 1.0], 1, max_attempts=0)


def test_run_grains_fail_on_tokens_consumed_exactly_once():
    """The injected-failure check mutates the shared ``fail_on`` set, so
    it must happen under the scheduler lock: with both workers holding a
    token for the same grain, each token kills exactly one attempt and
    the grain still completes within the cap."""
    # deterministic single-worker leg: the one token dies with attempt 1
    # and is gone for attempt 2 — a double-spend would fail both attempts
    fail_on = {(0, 5)}
    fns = [lambda g=g: float(g) for g in range(8)]
    assert run_grains(fns, 1, max_attempts=2, fail_on=fail_on) == \
        [float(g) for g in range(8)]
    assert fail_on == set()

    # concurrent leg: both workers hold a token for grain 5; whichever
    # attempts it consumes only its own token, and the grain still
    # completes within the cap
    fail_on = {(0, 5), (1, 5)}
    out = run_grains(fns, 2, max_attempts=3, fail_on=fail_on)
    assert out == [float(g) for g in range(8)]
    assert len(fail_on) <= 1  # one worker may simply never draw grain 5
