"""Model correctness beyond smoke: decode==forward consistency per family,
MoE dispatch equivalence, SSD vs naive recurrence oracle, GQA vs repeated
MHA, sliding-window masking."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, build_model
from repro.models.ssm import init_ssm, ssm_forward


def mk(family, **kw):
    base = dict(name="t", family=family, n_layers=2, d_model=32, n_heads=4,
                n_kv_heads=2, head_dim=8, d_ff=64, vocab_size=97,
                dtype="float32", remat=False)
    base.update(kw)
    return ModelConfig(**base)


FAMS = [
    ("dense", {}),
    ("dense", dict(attn_window=4, local_global_period=2,
                   attn_logit_softcap=50.0, final_logit_softcap=30.0,
                   post_block_norm=True, scale_embeddings=True,
                   act="gelu", tie_embeddings=True)),
    ("moe", dict(n_experts=4, top_k=2, capacity_factor=8.0,
                 moe_group_size=8)),
    ("ssm", dict(n_heads=0, n_kv_heads=1, head_dim=0, d_ff=0,
                 ssm_state=16, ssm_head_dim=8, ssm_chunk=4)),
    ("hybrid", dict(ssm_state=16, ssm_head_dim=8, ssm_chunk=4)),
]


@pytest.mark.parametrize("fam,kw", FAMS)
def test_decode_matches_forward(fam, kw):
    cfg = mk(fam, **kw)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 10
    tok = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, 97)
    full, _ = model.forward(params, tok)
    pre = S - 3
    lg, cache = model.prefill(params, tok[:, :pre], max_len=S)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, pre - 1]),
                               rtol=2e-3, atol=2e-3)
    for t in range(pre, S):
        lg, cache = model.decode_step(params, cache, tok[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, t]),
                                   rtol=2e-3, atol=2e-3,
                                   err_msg=f"{fam} step {t}")


def test_moe_impls_agree_no_drop():
    tok = jax.random.randint(jax.random.PRNGKey(0), (2, 12), 0, 97)
    outs = {}
    for impl in ("onehot", "scatter"):
        cfg = mk("moe", n_experts=4, top_k=2, capacity_factor=8.0,
                 moe_group_size=8, moe_impl=impl)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(1))
        logits, _ = model.forward(params, tok)
        outs[impl] = np.asarray(logits)
    np.testing.assert_allclose(outs["onehot"], outs["scatter"],
                               rtol=1e-4, atol=1e-4)


def test_moe_drops_are_consistent_between_impls():
    """Under capacity pressure both impls drop the same tokens (arrival
    order within group)."""
    tok = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0, 97)
    outs = {}
    for impl in ("onehot", "scatter"):
        cfg = mk("moe", n_experts=4, top_k=2, capacity_factor=0.5,
                 moe_group_size=16, moe_impl=impl)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(1))
        logits, _ = model.forward(params, tok)
        outs[impl] = np.asarray(logits)
    np.testing.assert_allclose(outs["onehot"], outs["scatter"],
                               rtol=1e-4, atol=1e-4)


def test_ssd_matches_naive_recurrence():
    """Chunked SSD == step-by-step linear recurrence (the SSM's oracle)."""
    cfg = mk("ssm", n_heads=0, n_kv_heads=1, head_dim=0, d_ff=0,
             ssm_state=8, ssm_head_dim=8, ssm_chunk=4)
    p = init_ssm(jax.random.PRNGKey(0), cfg)
    B, S, D = 2, 12, cfg.d_model
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, S, D))
    y_chunked = ssm_forward(p, x, cfg)
    # naive: decode step by step through the same params
    from repro.models.ssm import init_ssm_cache, ssm_decode
    conv = jnp.zeros((B, cfg.ssm_conv - 1, cfg.conv_dim))
    state = jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state))
    ys = []
    for t in range(S):
        y, conv, state = ssm_decode(p, x[:, t:t + 1], conv, state, cfg)
        ys.append(y)
    y_naive = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_naive),
                               rtol=3e-3, atol=3e-3)


def test_gqa_equals_mha_when_kv_repeated():
    """GQA with duplicated kv heads == MHA with those heads (sanity)."""
    from repro.models.attention import attn_forward, init_attn
    cfg_g = mk("dense", n_heads=4, n_kv_heads=2, head_dim=8)
    cfg_m = mk("dense", n_heads=4, n_kv_heads=4, head_dim=8)
    p = init_attn(jax.random.PRNGKey(0), cfg_g)
    # expand kv projections: kv head j of GQA serves q heads 2j, 2j+1
    wk = p["wk"].reshape(32, 2, 8)
    wk_m = jnp.stack([wk[:, 0], wk[:, 0], wk[:, 1], wk[:, 1]],
                     axis=1).reshape(32, 32)
    wv = p["wv"].reshape(32, 2, 8)
    wv_m = jnp.stack([wv[:, 0], wv[:, 0], wv[:, 1], wv[:, 1]],
                     axis=1).reshape(32, 32)
    pm = {"wq": p["wq"], "wk": wk_m, "wv": wv_m, "wo": p["wo"]}
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 32))
    pos = jnp.broadcast_to(jnp.arange(6), (2, 6))
    # NOTE: GQA groups q heads [2g, 2g+1] with kv head g (reshape order)
    out_g = attn_forward(p, x, cfg_g, positions=pos, is_local=False)
    out_m = attn_forward(pm, x, cfg_m, positions=pos, is_local=False)
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_m),
                               rtol=1e-4, atol=1e-5)


def test_sliding_window_blocks_distant_positions():
    """A token outside the window cannot influence the output."""
    cfg = mk("dense", attn_window=3, local_global_period=None)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (1, 10), 0, 97)
    tok2 = tok.at[0, 0].set((int(tok[0, 0]) + 1) % 97)  # perturb pos 0
    l1, _ = model.forward(params, tok)
    l2, _ = model.forward(params, tok2)
    # positions >= 3 are outside the window of pos 0 in every layer...
    # influence can propagate ~window per layer; with 2 layers, safe at >=7
    np.testing.assert_allclose(np.asarray(l1[0, 7:]), np.asarray(l2[0, 7:]),
                               rtol=1e-4, atol=1e-5)
    assert not np.allclose(np.asarray(l1[0, 0]), np.asarray(l2[0, 0]))


def test_vlm_prefix_changes_text_logits():
    cfg = mk("vlm", prefix_embeds=True, n_patches=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, 97)
    e1 = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (1, 4, 32))
    e2 = 0.1 * jax.random.normal(jax.random.PRNGKey(3), (1, 4, 32))
    l1, _ = model.forward(params, tok, e1)
    l2, _ = model.forward(params, tok, e2)
    assert l1.shape == (1, 10, 97)
    assert not np.allclose(np.asarray(l1[:, 4:]), np.asarray(l2[:, 4:]))
