"""End-to-end driver tests: train with checkpoint/restart (kill-resume),
serve decode loop, dry-run cell on a tiny forced-device mesh."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.launch import serve as serve_driver
from repro.launch import train as train_driver

REPO = os.path.dirname(os.path.dirname(__file__))


def test_train_loss_decreases(tmp_path):
    losses = train_driver.main([
        "--arch", "llama3-8b", "--smoke", "--steps", "25",
        "--batch", "4", "--seq", "64", "--lr", "1e-3",
        "--ckpt", str(tmp_path), "--ckpt-every", "10"])
    assert losses[-1] < losses[0]


def test_train_restart_resumes(tmp_path):
    """Simulated failure: run 12 steps, 'crash', rerun — must resume from
    the step-10 checkpoint and end at the same final step count."""
    args = ["--arch", "llama3-8b", "--smoke", "--batch", "2",
            "--seq", "32", "--ckpt", str(tmp_path), "--ckpt-every", "10",
            "--lr", "1e-3"]
    train_driver.main(args + ["--steps", "12"])
    from repro.checkpoint import CheckpointManager
    assert CheckpointManager(str(tmp_path)).latest_step() == 12
    losses = train_driver.main(args + ["--steps", "20"])
    # resumed run only executes steps 12..20
    assert len(losses) == 8


def test_serve_generates(capsys):
    gen = serve_driver.main(["--arch", "llama3-8b", "--smoke",
                             "--batch", "2", "--prompt-len", "8",
                             "--gen", "6"])
    assert gen.shape == (2, 6)
    assert (gen >= 0).all()


def test_serve_ssm_arch():
    gen = serve_driver.main(["--arch", "mamba2-1.3b", "--smoke",
                             "--batch", "2", "--prompt-len", "8",
                             "--gen", "4"])
    assert gen.shape == (2, 4)


DRYRUN_TINY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from unittest import mock
    import repro.launch.dryrun as dr
    # shrink the production mesh so the cell compiles quickly under test
    with mock.patch.object(dr, "make_production_mesh",
                           lambda multi_pod=False: jax.make_mesh(
                               (2, 2, 2) if multi_pod else (4, 2),
                               ("pod", "data", "model") if multi_pod
                               else ("data", "model"))):
        lowered, compiled, meta = dr.lower_cell(
            "llama3-8b", "train_4k", True,
            {"n_layers": 2, "d_model": 256, "n_heads": 8, "n_kv_heads": 2,
             "head_dim": 32, "d_ff": 512, "vocab_size": 1024})
        assert compiled is not None
        coll = dr.parse_collectives(compiled.as_text())
        assert coll["n_ops"] > 0, "multi-pod train must communicate"
        print("TINY_DRYRUN_OK", coll["total_bytes"] > 0)
""")


def test_dryrun_cell_tiny_mesh():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", DRYRUN_TINY],
                         capture_output=True, text=True, env=env, cwd=REPO)
    assert "TINY_DRYRUN_OK True" in out.stdout, out.stderr[-2000:]
