"""Per-kernel shape/dtype sweeps vs the numpy oracles (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pascal import comb
from repro.kernels import ops, ref


@pytest.mark.parametrize("B,m", [(1, 1), (3, 2), (7, 3), (130, 4),
                                 (64, 5), (5, 8), (256, 2)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_minor_det_sweep(B, m, dtype, rng):
    mats = rng.normal(size=(B, m, m)).astype(dtype)
    got = np.asarray(ops.minor_det(jnp.asarray(mats), tile=32))
    want = ref.minor_det_ref(mats)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-4)


def test_minor_det_singular_and_permuted(rng):
    m = 4
    a = rng.normal(size=(m, m)).astype(np.float32)
    sing = a.copy()
    sing[2] = sing[0]  # rank-deficient
    perm = a[[1, 0, 2, 3]]  # one swap -> -det
    mats = np.stack([a, sing, perm, np.eye(m, dtype=np.float32)])
    got = np.asarray(ops.minor_det(jnp.asarray(mats), tile=8))
    np.testing.assert_allclose(
        got, [np.linalg.det(a), 0.0, -np.linalg.det(a), 1.0],
        rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n,m", [(8, 5), (6, 3), (10, 2), (12, 6),
                                 (5, 5), (9, 1), (16, 3)])
@pytest.mark.parametrize("tile", [8, 64])
def test_unrank_sweep(n, m, tile):
    total = comb(n, m)
    qs = np.arange(total, dtype=np.int32)
    got = np.asarray(ops.unrank(jnp.asarray(qs), n, m, tile=tile))
    want = ref.unrank_ref(qs, n, m)
    assert (got == want).all()


@pytest.mark.parametrize("m,n", [(2, 6), (3, 7), (4, 8), (5, 8),
                                 (1, 5), (3, 3), (2, 12)])
def test_radic_fused_full(m, n, rng):
    A = rng.normal(size=(m, n)).astype(np.float32)
    got = float(ops.radic_det_pallas(jnp.asarray(A), tile=32))
    want = ref.radic_det_oracle(A)
    assert abs(got - want) <= 2e-3 * max(1.0, abs(want))


@pytest.mark.parametrize("q0,cnt", [(0, 1), (10, 17), (50, 6), (0, 56)])
def test_radic_fused_partial_ranges(q0, cnt, rng):
    A = rng.normal(size=(3, 8)).astype(np.float32)
    got = float(ops.radic_det_pallas(jnp.asarray(A), q_start=q0,
                                     count=cnt, tile=8))
    want = ref.radic_partial_ref(A, q0, cnt)
    assert abs(got - want) <= 1e-3 * max(1.0, abs(want))


def test_radic_fused_partials_compose(rng):
    """Grain partials sum to the full determinant (reduction idempotence)."""
    A = rng.normal(size=(3, 9)).astype(np.float32)
    total = comb(9, 3)
    cuts = [0, 20, 21, 60, total]
    parts = [float(ops.radic_det_pallas(jnp.asarray(A), q_start=a,
                                        count=b - a, tile=16))
             for a, b in zip(cuts[:-1], cuts[1:])]
    want = ref.radic_det_oracle(A)
    assert abs(sum(parts) - want) <= 2e-3 * max(1.0, abs(want))


def test_bf16_input_promoted(rng):
    """bf16 inputs are computed in f32 inside the kernel."""
    A = rng.normal(size=(3, 7)).astype(np.float32)
    got = float(ops.radic_det_pallas(jnp.asarray(A, jnp.bfloat16), tile=32))
    want = ref.radic_det_oracle(A.astype(np.float32))
    # bf16 storage of A costs precision; tolerance is loose by design
    assert abs(got - want) <= 0.05 * max(1.0, abs(want))


def test_int32_guard():
    with pytest.raises(OverflowError):
        ops.radic_det_pallas(jnp.ones((16, 40), jnp.float32))
