"""DetEngine/DetPlan battery: bit-identity against the pre-refactor
traced paths, plan-time validation ordering, degenerate-shape
normalization, and LRU cache semantics.

The engine's contract (DESIGN_ENGINE.md): a plan binds exactly the
statics the pre-engine paths bound and enters exactly the same jitted
programs, so routing through the engine — and re-planning after an LRU
eviction — must not move a single bit.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import (DetEngine, default_engine, make_batched_evaluator,
                        radic_det, radic_det_batched, radic_det_distributed,
                        validate_rank_space)
from repro.core.pascal import INT32_MAX, binom_table, comb
from repro.core.radic import _radic_det_batched_flat, _radic_det_flat

SHAPES = [(1, 5), (2, 6), (3, 8), (3, 3)]


def _statics(m, n, chunk):
    """The pre-refactor per-shape recipe, spelled out independently:
    int32 Pascal table (x64 off in tier-1), exact total, clamped chunk."""
    total = comb(n, m)
    table = jnp.asarray(binom_table(n, m, dtype=np.int32))
    return total, table, int(min(chunk, max(total, 1)))


def _mesh():
    devs = np.array(jax.devices())
    return Mesh(devs.reshape(len(devs)), ("workers",))


# ------------------------------------------------------------- bit-identity
@pytest.mark.parametrize("m,n", SHAPES)
def test_scalar_bit_identity_vs_traced_program(m, n, rng):
    """radic_det (now engine-routed) enters the same jitted program with
    the same statics the pre-refactor wrapper bound → identical bits."""
    A = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    total, table, chunk = _statics(m, n, 64)
    want = _radic_det_flat(A, table, total, chunk, False)
    got = radic_det(A, chunk=64)
    assert float(got) == float(want)


def test_scalar_kahan_bit_identity(rng):
    A = jnp.asarray(rng.normal(size=(3, 9)).astype(np.float32))
    total, table, chunk = _statics(3, 9, 32)
    want = _radic_det_flat(A, table, total, chunk, True)
    assert float(radic_det(A, chunk=32, kahan=True)) == float(want)


@pytest.mark.parametrize("cap", [1, 2, 8])
@pytest.mark.parametrize("m,n", SHAPES)
def test_batched_bit_identity_across_capacities(m, n, cap, rng):
    """Both the traced (capacity=None) and the AOT-lowered (capacity=cap)
    plans are the same XLA program as the direct jitted call."""
    As = jnp.asarray(rng.normal(size=(cap, m, n)).astype(np.float32))
    total, table, chunk = _statics(m, n, 64)
    want = np.asarray(_radic_det_batched_flat(As, table, total, chunk))
    eng = DetEngine()
    traced = eng.plan(m, n, chunk=64)
    assert not traced.lowered
    np.testing.assert_array_equal(np.asarray(traced(As)), want)
    aot = eng.plan(m, n, capacity=cap, chunk=64)
    assert aot.lowered
    np.testing.assert_array_equal(np.asarray(aot(As)), want)
    np.testing.assert_array_equal(
        np.asarray(radic_det_batched(As, chunk=64)), want)


def test_pallas_routing_bit_identity(rng):
    """The engine's pallas route is the same ops entry point the
    pre-refactor wrappers called directly."""
    from repro.kernels import ops
    As = jnp.asarray(rng.normal(size=(3, 2, 7)).astype(np.float32))
    want = np.asarray(ops.radic_det_batched_pallas(As, q_start=0,
                                                   count=comb(7, 2)))
    plan = DetEngine().plan(2, 7, backend="pallas")
    np.testing.assert_array_equal(np.asarray(plan(As)), want)
    A = As[0]
    want_s = float(ops.radic_det_pallas(A, q_start=0, count=comb(7, 2)))
    assert float(radic_det(A, backend="pallas")) == want_s


# --------------------------------------------------- validation before dispatch
def test_pallas_overflow_guard_runs_at_plan_time():
    """Regression (ISSUE 3 satellite): the pallas path historically
    dispatched before the C(n, m) width guard.  C(40, 16) > 2**31 must
    raise OverflowError at *plan* time for every pallas entry point —
    binding an evaluator must already fail, not its first call."""
    assert comb(40, 16) > INT32_MAX
    with pytest.raises(OverflowError):
        DetEngine().plan(16, 40, backend="pallas")
    with pytest.raises(OverflowError):
        make_batched_evaluator(16, 40, backend="pallas")
    with pytest.raises(OverflowError):
        radic_det(jnp.ones((16, 40), jnp.float32), backend="pallas")
    with pytest.raises(OverflowError):
        radic_det_batched(jnp.ones((2, 16, 40), jnp.float32),
                          backend="pallas")


def test_jnp_overflow_guard_points_at_grains():
    if jax.config.jax_enable_x64:
        pytest.skip("int32 guard is bypassed under x64")
    with pytest.raises(OverflowError, match="grains"):
        DetEngine().plan(16, 40)
    with pytest.raises(OverflowError):
        validate_rank_space(16, 40)


def test_grains_mode_has_no_width_limit():
    # C(40, 16) overflows int32 but host-bigint grain starts don't care
    assert validate_rank_space(16, 40, mesh_grains=True) == comb(40, 16)


# --------------------------------------------------------- degenerate m > n
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_degenerate_batched_is_device_program(backend, rng):
    """Regression (ISSUE 3 satellite): make_batched_evaluator's m > n
    fast-path used to hand back a host closure that ignored an explicit
    backend/mesh; the engine normalizes it to a jitted zeros *device*
    program for every configuration."""
    ev = make_batched_evaluator(4, 2, backend=backend)
    out = ev(rng.normal(size=(3, 4, 2)).astype(np.float32))
    assert isinstance(out, jax.Array)
    assert out.shape == (3,) and not np.asarray(out).any()


def test_degenerate_batched_with_mesh_is_device_program(rng):
    ev = make_batched_evaluator(4, 2, mesh=_mesh())
    out = ev(rng.normal(size=(3, 4, 2)).astype(np.float32))
    assert isinstance(out, jax.Array)
    assert out.shape == (3,) and not np.asarray(out).any()


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_degenerate_scalar_is_device_zero(backend):
    out = radic_det(jnp.ones((4, 2), jnp.float32), backend=backend)
    assert isinstance(out, jax.Array) and float(out) == 0.0
    out = radic_det_distributed(jnp.ones((4, 2), jnp.float32),
                                backend=backend)
    assert isinstance(out, jax.Array) and float(out) == 0.0


# ------------------------------------------------------------- cache semantics
def test_plan_cache_hit_returns_same_plan():
    eng = DetEngine()
    p1 = eng.plan(2, 6, capacity=4)
    p2 = eng.plan(2, 6, capacity=4)
    assert p1 is p2
    assert eng.cache_info()["hits"] == 1
    # any key ingredient changes → a different plan
    assert eng.plan(2, 6, capacity=8) is not p1
    assert eng.plan(2, 6, capacity=4, chunk=64) is not p1
    assert eng.plan(2, 6) is not p1


def test_lru_eviction_and_replan_bit_identity(rng):
    """Evicted shapes re-plan and reproduce identical bits — the property
    that makes the cache bound safe for long-tail shape traffic."""
    eng = DetEngine(max_plans=2)
    inputs = {}
    before = {}
    for m, n in [(1, 5), (2, 6), (3, 8)]:
        As = jnp.asarray(rng.normal(size=(4, m, n)).astype(np.float32))
        inputs[(m, n)] = As
        before[(m, n)] = np.asarray(eng.plan(m, n, capacity=4, chunk=64)(As))
    info = eng.cache_info()
    assert info["size"] == 2 and info["evictions"] == 1
    # (1, 5) was evicted (LRU); re-planning must not move a bit
    keys = [(k.m, k.n) for k in eng.cached_keys()]
    assert (1, 5) not in keys
    for m, n in [(1, 5), (2, 6), (3, 8)]:
        again = np.asarray(eng.plan(m, n, capacity=4, chunk=64)(
            inputs[(m, n)]))
        np.testing.assert_array_equal(again, before[(m, n)])
    assert eng.cache_info()["size"] == 2  # still bounded after re-plans


def test_lru_order_refreshes_on_hit():
    eng = DetEngine(max_plans=2)
    eng.plan(1, 5)
    eng.plan(2, 6)
    eng.plan(1, 5)  # refresh: (2, 6) is now the eviction candidate
    eng.plan(3, 8)
    keys = [(k.m, k.n) for k in eng.cached_keys()]
    assert (2, 6) not in keys and (1, 5) in keys and (3, 8) in keys


def test_mesh_plans_are_cached_across_calls(rng):
    """Equal meshes hash equal, so repeated distributed calls reuse one
    planned worker (grain starts unranked once, not per call)."""
    eng = DetEngine()
    A = jnp.asarray(rng.normal(size=(2, 6)).astype(np.float32))
    got1 = float(eng.det(A, mesh=_mesh()))
    got2 = float(eng.det(A, mesh=_mesh()))
    info = eng.cache_info()
    assert info["misses"] == 1 and info["hits"] == 1
    assert got1 == got2


def test_engine_validation_errors():
    eng = DetEngine()
    with pytest.raises(ValueError):
        eng.plan(2, 6, backend="cuda")
    with pytest.raises(ValueError):
        eng.plan(2, 6, batched=True, kahan=True)
    with pytest.raises(ValueError):
        eng.plan(2, 6, batched=False, capacity=4)
    with pytest.raises(ValueError):
        DetEngine(max_plans=0)


def test_default_engine_is_shared_and_swappable():
    from repro.core import set_default_engine
    assert default_engine() is default_engine()
    custom = DetEngine(max_plans=4)
    set_default_engine(custom)
    try:
        assert default_engine() is custom
        radic_det(jnp.ones((2, 5), jnp.float32), chunk=16)
        assert custom.cache_info()["size"] == 1
    finally:
        set_default_engine(None)
    assert default_engine() is not custom


def test_aot_donated_lowering_bit_identical(rng, monkeypatch):
    """The donated AOT lowering (TPU/GPU hot path) is the same XLA
    program: forcing it on (CPU ignores the donation hint with a
    warning, which is exactly why the engine gates it) must produce
    bit-identical results to the default lowering."""
    import warnings

    from repro.core import engine as E

    m, n, cap = 3, 8, 4
    As = rng.normal(size=(cap, m, n)).astype(np.float32)
    want = np.asarray(DetEngine().plan(m, n, batched=True, capacity=cap,
                                       dtype=np.float32)(jnp.asarray(As)))
    monkeypatch.setattr(E, "_donation_supported", lambda: True)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # CPU: "donated buffers not usable"
        plan = DetEngine().plan(m, n, batched=True, capacity=cap,
                                dtype=np.float32)
        got = np.asarray(plan(jnp.asarray(As)))
    assert plan.lowered is True
    np.testing.assert_array_equal(got, want)
