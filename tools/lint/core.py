"""reprolint pass framework: file model, suppressions, runner, CLI.

Design constraints (the reasons this file looks the way it does):

* **stdlib only.**  The CI lint job runs before any wheel install, so
  nothing here (or in passes.py) may import jax, numpy, or pytest.
* **Pure AST.**  Passes receive a parsed module + source lines; they
  never execute repo code, so a lint run cannot hang on device init.
* **Suppressions are comments**, because the linter must be overridable
  at the exact site where a human has proven the invariant by other
  means — and the justification belongs next to the override.

Suppression syntax (collected with ``tokenize`` since ``ast`` drops
comments):

* ``# reprolint: disable=<pass>[,<pass>...]`` on a line suppresses those
  passes for findings **on that line**.  On a ``def``/``class`` line it
  suppresses the whole body.
* ``# reprolint: disable-file=<pass>[,...]`` anywhere suppresses the
  pass for the entire file.
* ``all`` is accepted in place of a pass list.
"""

from __future__ import annotations

import argparse
import ast
import dataclasses
import io
import json
import re
import sys
import time
import tokenize
from pathlib import Path

__all__ = ["Finding", "LintError", "LintPass", "FileContext",
           "collect_files", "lint_file", "lint_paths", "main"]

_SUPPRESS_RE = re.compile(
    r"#\s*reprolint:\s*disable(?P<scope>-file)?\s*=\s*"
    r"(?P<passes>[A-Za-z0-9_,\- ]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""
    path: str
    line: int
    col: int
    pass_id: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: " \
               f"[{self.pass_id}] {self.message}"

    def to_json(self) -> dict:
        return {"path": self.path, "line": self.line, "col": self.col,
                "pass": self.pass_id, "message": self.message}


class LintError(Exception):
    """Unreadable / unparsable input — exit code 2, not a finding."""


class Suppressions:
    """Per-file suppression map parsed from comments."""

    def __init__(self, source: str, tree: ast.Module):
        self.file_level: set[str] = set()
        self.by_line: dict[int, set[str]] = {}
        try:
            tokens = tokenize.generate_tokens(io.StringIO(source).readline)
            for tok in tokens:
                if tok.type != tokenize.COMMENT:
                    continue
                m = _SUPPRESS_RE.search(tok.string)
                if not m:
                    continue
                ids = {p.strip() for p in m.group("passes").split(",")
                       if p.strip()}
                if m.group("scope"):
                    self.file_level |= ids
                else:
                    self.by_line.setdefault(tok.start[0], set()).update(ids)
        except tokenize.TokenError:
            pass  # ast parsed it; a tail tokenize hiccup is non-fatal
        # spans of defs/classes whose header line carries a suppression,
        # so a def-line comment covers the whole (possibly nested) body.
        self.def_spans: list[tuple[int, int, set[str]]] = []
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                ids = self.by_line.get(node.lineno)
                if ids:
                    end = getattr(node, "end_lineno", node.lineno)
                    self.def_spans.append((node.lineno, end, ids))

    def is_suppressed(self, pass_id: str, line: int) -> bool:
        if pass_id in self.file_level or "all" in self.file_level:
            return True
        ids = self.by_line.get(line, ())
        if pass_id in ids or "all" in ids:
            return True
        for start, end, span_ids in self.def_spans:
            if start <= line <= end and (pass_id in span_ids
                                         or "all" in span_ids):
                return True
        return False


class FileContext:
    """Everything a pass needs about one file: path, tree, aliases."""

    def __init__(self, path: str, source: str, tree: ast.Module):
        self.path = path          # normalized to forward slashes
        self.source = source
        self.tree = tree
        self.suppressions = Suppressions(source, tree)
        # name -> dotted module path, from every import in the file
        # (``import jax.numpy as jnp`` => {"jnp": "jax.numpy"};
        #  ``from jax import experimental as E`` => {"E": "jax.experimental"})
        self.import_aliases: dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.asname:
                        self.import_aliases[alias.asname] = alias.name
                    else:
                        root = alias.name.split(".", 1)[0]
                        self.import_aliases[root] = root
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and node.level == 0:
                for alias in node.names:
                    bound = alias.asname or alias.name
                    self.import_aliases[bound] = \
                        f"{node.module}.{alias.name}"

    def dotted(self, node: ast.AST) -> str | None:
        """``a.b.c`` for a Name/Attribute chain with the root resolved
        through this file's import aliases; None for non-chains."""
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        root = self.import_aliases.get(node.id, node.id)
        parts.append(root)
        return ".".join(reversed(parts))


class LintPass:
    """Base class: subclass, set ``id``, implement ``run``."""

    id = ""
    description = ""

    def applies(self, path: str) -> bool:
        return True

    def run(self, ctx: FileContext) -> list[Finding]:
        raise NotImplementedError

    def finding(self, ctx: FileContext, node: ast.AST,
                message: str) -> Finding:
        return Finding(ctx.path, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), self.id, message)


def collect_files(paths: list[str]) -> list[Path]:
    """Expand CLI args to .py files; missing paths raise LintError."""
    out: list[Path] = []
    for raw in paths:
        p = Path(raw)
        if p.is_dir():
            out.extend(sorted(p.rglob("*.py")))
        elif p.is_file():
            out.append(p)
        else:
            raise LintError(f"no such file or directory: {raw}")
    seen: set[Path] = set()
    uniq = []
    for p in out:
        if p not in seen:
            seen.add(p)
            uniq.append(p)
    return uniq


def lint_file(path: Path, passes) -> list[Finding]:
    try:
        source = path.read_text(encoding="utf-8")
    except OSError as e:
        raise LintError(f"cannot read {path}: {e}") from e
    norm = str(path).replace("\\", "/")
    try:
        tree = ast.parse(source, filename=norm)
    except SyntaxError as e:
        raise LintError(f"syntax error in {norm}:{e.lineno}: {e.msg}") from e
    ctx = FileContext(norm, source, tree)
    findings: list[Finding] = []
    for p in passes:
        if not p.applies(norm):
            continue
        for f in p.run(ctx):
            if not ctx.suppressions.is_suppressed(f.pass_id, f.line):
                findings.append(f)
    return findings


def lint_paths(paths: list[str], passes) -> tuple[list[Finding], int]:
    """Run ``passes`` over every .py under ``paths``.

    Returns (findings, files_scanned); raises LintError on unreadable
    or unparsable input.
    """
    files = collect_files(paths)
    findings: list[Finding] = []
    for f in files:
        findings.extend(lint_file(f, passes))
    findings.sort(key=lambda x: (x.path, x.line, x.col, x.pass_id))
    return findings, len(files)


def main(argv: list[str] | None = None) -> int:
    from .passes import ALL_PASSES, pass_ids  # late: keep import cheap

    parser = argparse.ArgumentParser(
        prog="python -m tools.lint",
        description="reprolint: AST invariant checks (see DESIGN_LINT.md)")
    parser.add_argument("paths", nargs="+",
                        help="files or directories to lint")
    parser.add_argument("--select", default=None, metavar="PASS[,PASS]",
                        help="run only these passes "
                             f"(available: {', '.join(pass_ids())})")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="machine-readable output on stdout")
    try:
        args = parser.parse_args(argv)
    except SystemExit as e:
        return 2 if e.code not in (0, None) else 0

    passes = ALL_PASSES
    if args.select:
        wanted = {s.strip() for s in args.select.split(",") if s.strip()}
        unknown = wanted - set(pass_ids())
        if unknown:
            print(f"reprolint: unknown pass(es): {', '.join(sorted(unknown))}",
                  file=sys.stderr)
            return 2
        passes = [p for p in ALL_PASSES if p.id in wanted]

    t0 = time.monotonic()
    try:
        findings, n_files = lint_paths(args.paths, passes)
    except LintError as e:
        print(f"reprolint: {e}", file=sys.stderr)
        return 2
    dt_ms = (time.monotonic() - t0) * 1e3

    counts: dict[str, int] = {}
    for f in findings:
        counts[f.pass_id] = counts.get(f.pass_id, 0) + 1

    if args.as_json:
        print(json.dumps({
            "version": 1,
            "files_scanned": n_files,
            "passes": [p.id for p in passes],
            "counts": counts,
            "findings": [f.to_json() for f in findings],
        }, indent=2, sort_keys=True))
    else:
        for f in findings:
            print(f.render())
        status = "clean" if not findings else \
            f"{len(findings)} finding(s): " + ", ".join(
                f"{k}={v}" for k, v in sorted(counts.items()))
        print(f"reprolint: {n_files} file(s), {len(passes)} pass(es), "
              f"{dt_ms:.0f} ms — {status}")
    return 1 if findings else 0
