"""The five reprolint passes.  Catalog + rationale in DESIGN_LINT.md.

Every pass is a lexical approximation of a dynamic invariant; each class
docstring states the approximation so a reader knows what a clean run
does and does not prove.
"""

from __future__ import annotations

import ast

from .core import FileContext, Finding, LintPass

__all__ = ["ALL_PASSES", "pass_ids", "CompatSeamPass", "LockDisciplinePass",
           "WireSafetyPass", "TracerHygienePass", "OverflowGuardPass"]


# --------------------------------------------------------------------------
# 1. compat-seam
# --------------------------------------------------------------------------

class CompatSeamPass(LintPass):
    """shard_map spellings only inside ``parallel/compat.py``.

    jax renamed its SPMD surface across the versions this repo supports;
    ``repro.parallel.compat`` is the single translation seam.  This pass
    flags *references* — imports (plain, aliased, ``from``-form),
    resolved attribute chains (``import jax as j; j.shard_map``), and
    ``getattr(jax, "shard_map")`` spellings.  Strings and docstrings are
    never flagged (this is an AST pass, not a grep).
    """

    id = "compat-seam"
    description = "jax.shard_map references outside parallel/compat.py"

    EXEMPT_SUFFIX = "repro/parallel/compat.py"

    @staticmethod
    def _forbidden(dotted: str) -> bool:
        return (dotted == "jax.shard_map"
                or dotted == "jax.experimental.shard_map"
                or dotted.startswith("jax.shard_map.")
                or dotted.startswith("jax.experimental.shard_map."))

    def applies(self, path: str) -> bool:
        return not path.endswith(self.EXEMPT_SUFFIX)

    def run(self, ctx: FileContext) -> list[Finding]:
        findings: list[Finding] = []

        def flag(node: ast.AST, what: str) -> None:
            findings.append(self.finding(
                ctx, node, f"{what} — all shard_map access must go "
                           f"through repro.parallel.compat"))

        class V(ast.NodeVisitor):
            def visit_Import(self, node: ast.Import) -> None:
                for alias in node.names:
                    if CompatSeamPass._forbidden(alias.name):
                        flag(node, f"import of '{alias.name}'")

            def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
                if node.module and node.level == 0:
                    if CompatSeamPass._forbidden(node.module):
                        flag(node, f"import from '{node.module}'")
                        return
                    for alias in node.names:
                        full = f"{node.module}.{alias.name}"
                        if CompatSeamPass._forbidden(full):
                            flag(node, f"import of '{full}'")

            def visit_Attribute(self, node: ast.Attribute) -> None:
                dotted = ctx.dotted(node)
                if dotted and CompatSeamPass._forbidden(dotted):
                    flag(node, f"attribute reference '{dotted}'")
                    return  # don't re-flag the inner chain
                self.generic_visit(node)

            def visit_Call(self, node: ast.Call) -> None:
                if (isinstance(node.func, ast.Name)
                        and node.func.id == "getattr"
                        and len(node.args) >= 2
                        and isinstance(node.args[1], ast.Constant)
                        and isinstance(node.args[1].value, str)
                        and "shard_map" in node.args[1].value):
                    base = ctx.dotted(node.args[0])
                    if base in ("jax", "jax.experimental") or (
                            base and CompatSeamPass._forbidden(base)):
                        flag(node, f"getattr({base}, "
                                   f"{node.args[1].value!r})")
                self.generic_visit(node)

        V().visit(ctx.tree)
        return findings


# --------------------------------------------------------------------------
# 2. lock-discipline
# --------------------------------------------------------------------------

class LockDisciplinePass(LintPass):
    """Guarded-by checker for classes that declare ``_GUARDED_BY``.

    A class opts in with a registry mapping attribute name -> lock
    attribute name (or tuple of acceptable lock names, for a Condition
    sharing its lock):

        _GUARDED_BY = {"_pending": "_lock", "_responses": ("_resp_cv",)}

    Every ``self.<attr>`` read or write of a registered attribute must
    be **lexically** inside ``with self.<lock>:`` for one of its locks,
    or inside ``__init__``.  Lexical containment is the approximation:
    a helper documented as "caller holds the lock" does not pass — take
    the (re-entrant) lock in the helper or suppress with a justification.
    """

    id = "lock-discipline"
    description = "_GUARDED_BY attributes accessed outside their lock"

    INIT_METHODS = {"__init__", "__post_init__", "__new__", "__del__"}

    def applies(self, path: str) -> bool:
        # scoped to the concurrent serving tier — which since the
        # autoscaler includes the runtime health modules (Watchdog
        # beats cross threads) and since the plan store includes the
        # checkpoint package (background writer thread) — plus lint
        # fixtures/tests
        return ("repro/launch/" in path or "repro/core/engine" in path
                or "repro/runtime/" in path or "repro/checkpoint/" in path
                or "test" in path or "fixture" in path)

    @staticmethod
    def _registry(cls: ast.ClassDef) -> dict[str, tuple[str, ...]]:
        reg: dict[str, tuple[str, ...]] = {}
        for stmt in cls.body:
            targets: list[ast.expr] = []
            value: ast.expr | None = None
            if isinstance(stmt, ast.Assign):
                targets, value = stmt.targets, stmt.value
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                targets, value = [stmt.target], stmt.value
            if not any(isinstance(t, ast.Name) and t.id == "_GUARDED_BY"
                       for t in targets):
                continue
            if not isinstance(value, ast.Dict):
                continue
            for k, v in zip(value.keys, value.values):
                if not (isinstance(k, ast.Constant)
                        and isinstance(k.value, str)):
                    continue
                if isinstance(v, ast.Constant) and isinstance(v.value, str):
                    reg[k.value] = (v.value,)
                elif isinstance(v, (ast.Tuple, ast.List)):
                    locks = tuple(e.value for e in v.elts
                                  if isinstance(e, ast.Constant)
                                  and isinstance(e.value, str))
                    if locks:
                        reg[k.value] = locks
        return reg

    def run(self, ctx: FileContext) -> list[Finding]:
        findings: list[Finding] = []
        for cls in [n for n in ast.walk(ctx.tree)
                    if isinstance(n, ast.ClassDef)]:
            reg = self._registry(cls)
            if not reg:
                continue
            for item in cls.body:
                if not isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                if item.name in self.INIT_METHODS:
                    continue
                self._walk_method(ctx, item, reg, findings)
        return findings

    def _walk_method(self, ctx: FileContext, func: ast.AST,
                     reg: dict[str, tuple[str, ...]],
                     findings: list[Finding]) -> None:
        def held_locks(node: ast.With | ast.AsyncWith) -> set[str]:
            out: set[str] = set()
            for it in node.items:
                e = it.context_expr
                # with self._lock:  /  with self._cv:  (bare attribute)
                if (isinstance(e, ast.Attribute)
                        and isinstance(e.value, ast.Name)
                        and e.value.id == "self"):
                    out.add(e.attr)
            return out

        def walk(node: ast.AST, locks: frozenset[str]) -> None:
            if isinstance(node, (ast.With, ast.AsyncWith)):
                inner = locks | held_locks(node)
                for it in node.items:
                    walk(it, locks)
                for child in node.body:
                    walk(child, inner)
                return
            if (isinstance(node, ast.Attribute)
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "self"
                    and node.attr in reg
                    and not (set(reg[node.attr]) & locks)):
                kind = ("write" if isinstance(node.ctx,
                                              (ast.Store, ast.Del))
                        else "read")
                want = " or ".join(f"self.{lk}" for lk in reg[node.attr])
                findings.append(self.finding(
                    ctx, node,
                    f"{kind} of guarded attribute 'self.{node.attr}' "
                    f"outside 'with {want}:'"))
            for child in ast.iter_child_nodes(node):
                walk(child, locks)

        for stmt in ast.iter_child_nodes(func):
            walk(stmt, frozenset())


# --------------------------------------------------------------------------
# 3. wire-safety
# --------------------------------------------------------------------------

class WireSafetyPass(LintPass):
    """Payloads of ``link.send(...)`` / ``send_raw(...)`` must be built
    from the plain-type wire grammar.

    Allowed: literals, f-strings, containers of allowed values,
    conversion builtins (``int``/``float``/``str``/...), registered
    NamedTuple constructors, and trusted producer methods
    (``.snapshot()``, ``.to_wire()``).  Flagged: lambdas, generator
    expressions, numpy/jax-rooted calls or attributes, bare references
    to locally-defined functions, and unvetted call results inline in
    a message (bind to a name first, or register the producer).

    Plain variable references are opaque-allowed — the pass checks how
    a message is *built* at the send site, not dataflow into it.  That
    is exactly the shape of the PR-5 regression it exists to prevent
    (``np.int64`` built inline into a stats dict).

    Registered *descriptor builders* (``shm_descriptor``: the shm
    ring's ``(offset, shape, dtype)`` payload descriptor) are vetted at
    every build site, not just inside sends — their result goes onto
    the wire verbatim, usually bound to a name first, which the
    send-site grammar deliberately treats as opaque.  Their arguments
    must satisfy the same plain grammar.
    """

    id = "wire-safety"
    description = "non-plain values built into wire messages"

    SEND_NAMES = {"send", "send_raw"}
    DESCRIPTOR_BUILDERS = {"shm_descriptor"}
    SAFE_BUILTINS = {"str", "int", "float", "bool", "bytes", "list",
                     "tuple", "dict", "set", "sorted", "len", "repr",
                     "min", "max", "abs", "round", "sum", "format", "ord"}
    SAFE_METHODS = {"to_wire", "snapshot", "tolist", "item", "copy",
                    "decode", "encode", "strip", "format", "get", "items",
                    "keys", "values"}
    REGISTERED_NAMEDTUPLES = {"PlanKey"}
    NUMERIC_MODULE_ROOTS = {"numpy", "jax"}

    def applies(self, path: str) -> bool:
        return ("repro/launch/" in path or "test" in path
                or "fixture" in path)

    def run(self, ctx: FileContext) -> list[Finding]:
        findings: list[Finding] = []
        local_funcs = {n.name for n in ast.walk(ctx.tree)
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))}

        def rooted_numeric(node: ast.AST) -> str | None:
            dotted = ctx.dotted(node)
            if dotted and dotted.split(".", 1)[0] in \
                    self.NUMERIC_MODULE_ROOTS:
                return dotted
            return None

        def check(node: ast.AST) -> None:
            if isinstance(node, ast.Constant) or node is None:
                return
            if isinstance(node, ast.JoinedStr):
                return
            if isinstance(node, ast.Lambda):
                findings.append(self.finding(
                    ctx, node, "lambda in wire message (unpicklable "
                               "closure)"))
                return
            if isinstance(node, ast.GeneratorExp):
                findings.append(self.finding(
                    ctx, node, "generator expression in wire message "
                               "(unpicklable); materialize a list"))
                return
            if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
                for e in node.elts:
                    check(e)
                return
            if isinstance(node, ast.Dict):
                for k in node.keys:
                    if k is not None:
                        check(k)
                for v in node.values:
                    check(v)
                return
            if isinstance(node, ast.Starred):
                check(node.value)
                return
            if isinstance(node, (ast.ListComp, ast.SetComp)):
                check(node.elt)
                return
            if isinstance(node, ast.DictComp):
                check(node.key)
                check(node.value)
                return
            if isinstance(node, ast.IfExp):
                check(node.body)
                check(node.orelse)
                return
            if isinstance(node, ast.BinOp):
                check(node.left)
                check(node.right)
                return
            if isinstance(node, ast.UnaryOp):
                check(node.operand)
                return
            if isinstance(node, ast.BoolOp):
                for v in node.values:
                    check(v)
                return
            if isinstance(node, ast.Compare):
                check(node.left)
                for c in node.comparators:
                    check(c)
                return
            if isinstance(node, ast.Call):
                dotted = rooted_numeric(node.func)
                if dotted:
                    findings.append(self.finding(
                        ctx, node, f"'{dotted}(...)' builds a numpy/jax "
                                   f"object into a wire message; convert "
                                   f"with float()/int()/.tolist() first"))
                    return
                if isinstance(node.func, ast.Name):
                    if node.func.id in self.SAFE_BUILTINS:
                        return  # terminal converter: result is plain
                    if node.func.id in (self.REGISTERED_NAMEDTUPLES
                                        | self.DESCRIPTOR_BUILDERS):
                        for a in node.args:
                            check(a)
                        for kw in node.keywords:
                            check(kw.value)
                        return
                elif isinstance(node.func, ast.Attribute):
                    if node.func.attr in self.SAFE_METHODS:
                        return  # trusted producer
                findings.append(self.finding(
                    ctx, node, "unvetted call result built into a wire "
                               "message; bind it to a variable or add "
                               "the producer to the wire allowlist"))
                return
            if isinstance(node, ast.Name):
                if node.id in local_funcs:
                    findings.append(self.finding(
                        ctx, node, f"function object '{node.id}' in wire "
                                   f"message (unpicklable across "
                                   f"transports)"))
                return  # opaque variable: allowed (see docstring)
            if isinstance(node, ast.Attribute):
                dotted = rooted_numeric(node)
                if dotted:
                    findings.append(self.finding(
                        ctx, node, f"numpy/jax attribute '{dotted}' in "
                                   f"wire message"))
                return  # opaque attribute: allowed
            if isinstance(node, ast.Subscript):
                check(node.value)
                return
            # anything else (await, walrus, ...) is out of grammar scope

        for call in [n for n in ast.walk(ctx.tree)
                     if isinstance(n, ast.Call)]:
            fn = call.func
            name = fn.id if isinstance(fn, ast.Name) else (
                fn.attr if isinstance(fn, ast.Attribute) else None)
            if name in self.DESCRIPTOR_BUILDERS:
                # a descriptor build site is a send site by proxy: the
                # tuple it returns crosses the wire verbatim
                for a in call.args:
                    check(a)
                for kw in call.keywords:
                    check(kw.value)
                continue
            if name not in self.SEND_NAMES:
                continue
            for a in call.args:
                check(a)
            for kw in call.keywords:
                check(kw.value)
        return findings


# --------------------------------------------------------------------------
# 4. tracer-hygiene
# --------------------------------------------------------------------------

class TracerHygienePass(LintPass):
    """No Python control flow or host escapes on traced values.

    Analyzed functions: ``@jax.jit`` / ``@functools.partial(jax.jit,
    ...)`` decorated defs, defs lowered via a ``jax.jit(f, ...)`` call
    form in the same file, and Pallas kernel bodies (first argument of
    ``pl.pallas_call`` — bare name or ``functools.partial(name, ...)``
    with the partial-bound leading params treated as static).

    Tainted = non-static parameters (``static_argnums``/``argnames``
    honored) plus direct ``x = param`` aliases.  Flagged on tainted
    values: ``if``/``while``/``assert`` tests, ``float()``/``int()``/
    ``bool()``, ``.item()``/``.tolist()``, and ``np.*(...)`` calls.
    ``x is None``, ``isinstance``, ``len()``, and ``.shape``/``.ndim``/
    ``.dtype`` uses are trace-time static and exempt.
    """

    id = "tracer-hygiene"
    description = "Python control flow / host escapes on traced values"

    STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "weak_type",
                    "sharding", "itemsize"}
    STATIC_CALLS = {"len", "isinstance", "type", "hasattr", "getattr"}
    HOST_CASTS = {"float", "int", "bool", "complex"}
    HOST_METHODS = {"item", "tolist"}

    def run(self, ctx: FileContext) -> list[Finding]:
        findings: list[Finding] = []
        funcs_by_name: dict[str, list[ast.FunctionDef]] = {}
        for n in ast.walk(ctx.tree):
            if isinstance(n, ast.FunctionDef):
                funcs_by_name.setdefault(n.name, []).append(n)

        analyzed: set[tuple[int, frozenset]] = set()
        targets: list[tuple[ast.FunctionDef, set[str]]] = []

        def is_jit(node: ast.AST) -> bool:
            d = ctx.dotted(node)
            return d in ("jax.jit", "jit") or (
                d is not None and d.endswith(".jit"))

        def static_names(fn: ast.FunctionDef,
                         kwargs: list[ast.keyword]) -> set[str]:
            params = [a.arg for a in
                      fn.args.posonlyargs + fn.args.args]
            statics: set[str] = set()
            for kw in kwargs:
                if kw.arg == "static_argnames":
                    v = kw.value
                    vals = v.elts if isinstance(
                        v, (ast.Tuple, ast.List)) else [v]
                    statics |= {e.value for e in vals
                                if isinstance(e, ast.Constant)
                                and isinstance(e.value, str)}
                elif kw.arg == "static_argnums":
                    v = kw.value
                    vals = v.elts if isinstance(
                        v, (ast.Tuple, ast.List)) else [v]
                    for e in vals:
                        if isinstance(e, ast.Constant) \
                                and isinstance(e.value, int) \
                                and 0 <= e.value < len(params):
                            statics.add(params[e.value])
            return statics

        def add_target(fn: ast.FunctionDef, statics: set[str],
                       n_bound: int = 0) -> None:
            params = [a.arg for a in
                      fn.args.posonlyargs + fn.args.args]
            params = params[n_bound:]
            traced = {p for p in params
                      if p not in statics and p != "self"}
            key = (id(fn), frozenset(traced))
            if traced and key not in analyzed:
                analyzed.add(key)
                targets.append((fn, traced))

        # decorated defs
        for fn in [n for n in ast.walk(ctx.tree)
                   if isinstance(n, ast.FunctionDef)]:
            for dec in fn.decorator_list:
                if is_jit(dec):
                    add_target(fn, set())
                elif isinstance(dec, ast.Call):
                    d = ctx.dotted(dec.func)
                    if d in ("functools.partial", "partial") \
                            and dec.args and is_jit(dec.args[0]):
                        add_target(fn, static_names(fn, dec.keywords))
                    elif is_jit(dec.func):
                        add_target(fn, static_names(fn, dec.keywords))

        # call forms: jax.jit(f, ...) and pl.pallas_call(kernel, ...)
        for call in [n for n in ast.walk(ctx.tree)
                     if isinstance(n, ast.Call)]:
            d = ctx.dotted(call.func)
            if d is None:
                continue
            if is_jit(call.func) and call.args \
                    and isinstance(call.args[0], ast.Name):
                for fn in funcs_by_name.get(call.args[0].id, []):
                    add_target(fn, static_names(fn, call.keywords))
            elif d.endswith("pallas_call") and call.args:
                kern = call.args[0]
                if isinstance(kern, ast.Name):
                    for fn in funcs_by_name.get(kern.id, []):
                        add_target(fn, set())
                elif isinstance(kern, ast.Call):
                    kd = ctx.dotted(kern.func)
                    if kd in ("functools.partial", "partial") \
                            and kern.args \
                            and isinstance(kern.args[0], ast.Name):
                        for fn in funcs_by_name.get(kern.args[0].id, []):
                            add_target(fn, set(),
                                       n_bound=len(kern.args) - 1)

        for fn, traced in targets:
            findings.extend(self._check_body(ctx, fn, traced))
        return findings

    def _tainted_use(self, node: ast.AST, taint: set[str]) -> str | None:
        """Name of a tainted value *used as a value* in ``node``, after
        pruning trace-time-static subexpressions; None if clean."""
        def scan(n: ast.AST) -> str | None:
            if isinstance(n, ast.Attribute) \
                    and n.attr in self.STATIC_ATTRS:
                return None  # x.shape etc: static at trace time
            if isinstance(n, ast.Call):
                d = n.func
                if isinstance(d, ast.Name) \
                        and d.id in self.STATIC_CALLS:
                    return None  # len(x), isinstance(x, ...)
            if isinstance(n, ast.Compare) and all(
                    isinstance(op, (ast.Is, ast.IsNot))
                    for op in n.ops) and all(
                    isinstance(c, ast.Constant) and c.value is None
                    for c in n.comparators):
                return None  # x is None: tracers are never None
            if isinstance(n, ast.Name) and n.id in taint:
                return n.id
            for child in ast.iter_child_nodes(n):
                hit = scan(child)
                if hit:
                    return hit
            return None
        return scan(node)

    def _check_body(self, ctx: FileContext, fn: ast.FunctionDef,
                    traced: set[str]) -> list[Finding]:
        findings: list[Finding] = []

        def walk(node: ast.AST, taint: set[str]) -> None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not fn:
                # nested def: its params shadow outer traced names
                inner_params = {a.arg for a in
                                node.args.posonlyargs + node.args.args}
                sub = taint - inner_params
                for child in node.body:
                    walk(child, sub)
                return
            if isinstance(node, ast.Assign) \
                    and isinstance(node.value, ast.Name) \
                    and node.value.id in taint:
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        taint.add(t.id)
            if isinstance(node, (ast.If, ast.While)):
                hit = self._tainted_use(node.test, taint)
                if hit:
                    kw = "while" if isinstance(node, ast.While) else "if"
                    findings.append(self.finding(
                        ctx, node.test,
                        f"Python '{kw}' on traced value '{hit}' — use "
                        f"jnp.where / lax.cond, or mark it static"))
            elif isinstance(node, ast.Assert):
                hit = self._tainted_use(node.test, taint)
                if hit:
                    findings.append(self.finding(
                        ctx, node,
                        f"'assert' on traced value '{hit}' — use "
                        f"checkify or a plan-time guard"))
            elif isinstance(node, ast.Call):
                f = node.func
                if isinstance(f, ast.Name) and f.id in self.HOST_CASTS:
                    hit = next((self._tainted_use(a, taint)
                                for a in node.args
                                if self._tainted_use(a, taint)), None)
                    if hit:
                        findings.append(self.finding(
                            ctx, node,
                            f"host cast '{f.id}()' on traced value "
                            f"'{hit}' forces device sync inside jit"))
                elif isinstance(f, ast.Attribute):
                    if f.attr in self.HOST_METHODS \
                            and self._tainted_use(f.value, taint):
                        findings.append(self.finding(
                            ctx, node,
                            f"host escape '.{f.attr}()' on traced value "
                            f"inside jit"))
                    else:
                        d = ctx.dotted(f)
                        if d and d.split(".", 1)[0] == "numpy":
                            hit = next((self._tainted_use(a, taint)
                                        for a in node.args
                                        if self._tainted_use(a, taint)),
                                       None)
                            if hit:
                                findings.append(self.finding(
                                    ctx, node,
                                    f"'{d}(...)' on traced value "
                                    f"'{hit}' — numpy calls escape the "
                                    f"trace; use jnp"))
            for child in ast.iter_child_nodes(node):
                walk(child, taint)

        taint = set(traced)
        for stmt in fn.body:
            walk(stmt, taint)
        return findings


# --------------------------------------------------------------------------
# 5. overflow-guard
# --------------------------------------------------------------------------

class OverflowGuardPass(LintPass):
    """``binom_table`` / ``unrank_tile`` call sites must be dominated by
    a plan-time rank-space guard.

    The Radic walk enumerates C(n, m) minors; the int32 rank arithmetic
    in the kernels silently wraps past 2**31-1, so every table build or
    unranking outside the engine's own plan construction must be
    lexically preceded — in the same or an enclosing scope — by
    ``validate_rank_space(...)`` or ``plan_statics(...)``.  Exempt: the
    guard's home (``core/engine.py``), the table builder itself
    (``core/pascal.py``), and the kernel-helper def site
    (``kernels/common.py``).
    """

    id = "overflow-guard"
    description = "unguarded binom_table / unrank_tile call sites"

    TARGETS = {"binom_table", "unrank_tile"}
    GUARDS = {"validate_rank_space", "plan_statics"}
    EXEMPT_SUFFIXES = ("repro/core/engine.py", "repro/core/pascal.py",
                       "repro/kernels/common.py")

    def applies(self, path: str) -> bool:
        return not path.endswith(self.EXEMPT_SUFFIXES)

    @staticmethod
    def _callee_name(call: ast.Call) -> str | None:
        f = call.func
        if isinstance(f, ast.Name):
            return f.id
        if isinstance(f, ast.Attribute):
            return f.attr
        return None

    def run(self, ctx: FileContext) -> list[Finding]:
        findings: list[Finding] = []

        # scope chain per node: module + enclosing function defs
        parents: dict[ast.AST, ast.AST] = {}
        for node in ast.walk(ctx.tree):
            for child in ast.iter_child_nodes(node):
                parents[child] = node

        def scope_chain(node: ast.AST) -> list[ast.AST]:
            chain: list[ast.AST] = []
            cur: ast.AST | None = parents.get(node)
            while cur is not None:
                if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                    ast.Module)):
                    chain.append(cur)
                cur = parents.get(cur)
            return chain

        guard_lines_by_scope: dict[ast.AST, list[int]] = {}
        target_calls: list[ast.Call] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = self._callee_name(node)
            if name in self.GUARDS:
                for scope in scope_chain(node):
                    guard_lines_by_scope.setdefault(scope, []) \
                        .append(node.lineno)
            elif name in self.TARGETS:
                target_calls.append(node)

        for call in target_calls:
            name = self._callee_name(call)
            guarded = any(
                g < call.lineno
                for scope in scope_chain(call)
                for g in guard_lines_by_scope.get(scope, ()))
            if not guarded:
                findings.append(self.finding(
                    ctx, call,
                    f"'{name}(...)' not dominated by "
                    f"validate_rank_space()/plan_statics() — int32 rank "
                    f"arithmetic can overflow unguarded"))
        return findings


ALL_PASSES: list[LintPass] = [
    CompatSeamPass(),
    LockDisciplinePass(),
    WireSafetyPass(),
    TracerHygienePass(),
    OverflowGuardPass(),
]


def pass_ids() -> list[str]:
    return [p.id for p in ALL_PASSES]
