"""reprolint — stdlib-``ast`` static analysis for the repo's standing
invariants.

Five passes (see DESIGN_LINT.md for the catalog and the rationale):

* ``compat-seam``     — shard_map spellings only inside parallel/compat.py
* ``lock-discipline`` — ``_GUARDED_BY`` attributes touched only under lock
* ``wire-safety``     — link.send() payloads built from plain types
* ``tracer-hygiene``  — no Python control flow / host escapes on tracers
* ``overflow-guard``  — binom_table/unrank_tile dominated by a rank guard

Pure stdlib: importing this package must never import jax/numpy, so the
CI lint job runs on a bare Python with no wheel install.
"""

from .core import (Finding, LintError, collect_files, lint_file, lint_paths,
                   main)
from .passes import ALL_PASSES, pass_ids

__all__ = ["Finding", "LintError", "ALL_PASSES", "pass_ids",
           "collect_files", "lint_file", "lint_paths", "main"]
