"""Repo tooling (lint, CI gates).  Not shipped with ``repro``."""
