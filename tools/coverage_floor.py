#!/usr/bin/env python
"""Per-file line-coverage floor gate for ``src/repro/launch/``.

CI's ``tier4-transport`` job runs the transport/front batteries under
``coverage`` and publishes the report as a per-commit artifact; this
script is the regression gate on top of it: **no serving-layer file may
fall below its recorded floor**.  The floors are the measured coverage
of the job's own test selection at the time the transport seam landed
(rounded down a few points for run-to-run noise) — raise them when the
batteries grow, never lower them to make a PR pass.

Files floored at 0 are the launch-layer modules this job's selection
does not exercise at all (training/serving drivers covered by tier-1,
and worker-subprocess entry points that run outside the measured
process); they are listed in the summary so a future test that starts
covering them can claim a real floor.

Usage: ``python tools/coverage_floor.py <coverage.json>``
(the output of ``coverage json``).
"""

import json
import os
import sys

# floor: minimum percent line coverage (coverage.py "percent_covered")
FLOORS = {
    "det_front.py": 80.0,   # tests/test_det_front.py + fault battery
    "transport.py": 70.0,   # fault battery + props (+ in-thread daemons)
    "det_queue.py": 70.0,   # its own battery + every front/queue path
    "det_serve.py": 55.0,   # in-process CLI legs appended by the CI job
    "__init__.py": 0.0,
}
DEFAULT_FLOOR = 0.0  # un-exercised by this job's selection (see docstring)


def main(path: str) -> int:
    with open(path) as fh:
        data = json.load(fh)
    rows = []
    for fname, rec in sorted(data.get("files", {}).items()):
        norm = fname.replace(os.sep, "/")
        if "repro/launch/" not in norm:
            continue
        base = norm.rsplit("/", 1)[-1]
        pct = float(rec["summary"]["percent_covered"])
        floor = FLOORS.get(base, DEFAULT_FLOOR)
        rows.append((base, pct, floor))
    if not rows:
        print("coverage_floor: no src/repro/launch/ files in the report",
              file=sys.stderr)
        return 2
    failures = []
    print(f"{'file':<16} {'covered%':>9} {'floor%':>7}  status")
    for base, pct, floor in rows:
        ok = pct >= floor
        print(f"{base:<16} {pct:>8.1f} {floor:>7.1f}  "
              f"{'ok' if ok else 'BELOW FLOOR'}")
        if not ok:
            failures.append(base)
    if failures:
        print(f"coverage_floor: {len(failures)} file(s) regressed below "
              f"their floor: {', '.join(failures)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1]))
