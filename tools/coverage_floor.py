#!/usr/bin/env python
"""Per-file line-coverage floor gate for the serving layer and reprolint.

CI's ``tier4-transport`` job runs the transport/front batteries (plus
the reprolint fixture battery) under ``coverage`` and publishes the
report as a per-commit artifact; this script is the regression gate on
top of it: **no gated file may fall below its recorded floor**.  The
floors are the measured coverage of the job's own test selection at the
time each group landed (rounded down a few points for run-to-run
noise) — raise them when the batteries grow, never lower them to make
a PR pass.

Files floored at 0 are modules this job's selection does not exercise
in-process (training/serving drivers covered by tier-1,
worker-subprocess entry points, and ``tools/lint/__main__.py`` which
only runs in the lint job's separate interpreter); they are listed in
the summary so a future test that starts covering them can claim a
real floor.

Usage: ``python tools/coverage_floor.py <coverage.json>``
(the output of ``coverage json``).
"""

import json
import os
import sys

# group prefix -> {basename: minimum percent line coverage
#                  (coverage.py "percent_covered")}
GROUPS = {
    "repro/launch/": {
        "det_front.py": 80.0,   # tests/test_det_front.py + fault battery
        "transport.py": 70.0,   # fault battery + props (+ in-thread daemons)
        "det_queue.py": 70.0,   # its own battery + every front/queue path
        "det_serve.py": 55.0,   # in-process CLI legs appended by the CI job
        "autoscale.py": 80.0,   # tests/test_autoscale.py + --autoscale smoke
        "__init__.py": 0.0,
    },
    "repro/runtime/": {
        "watchdog.py": 80.0,    # tests/test_runtime.py + test_substrates.py
        "stragglers.py": 80.0,  # run_grains failure/speculation batteries
        "elastic.py": 70.0,     # choose_mesh battery (build_mesh needs jax
                                # devices; partially exercised)
        "__init__.py": 90.0,    # imported by every runtime test
    },
    "repro/checkpoint/": {
        "manager.py": 85.0,     # tests/test_checkpoint.py regression battery
        "plan_store.py": 80.0,  # store round-trip/invalidation + warm-start
        "__init__.py": 90.0,    # imported by every checkpoint test
    },
    "tools/lint/": {
        "core.py": 80.0,        # tests/test_lint.py CLI/JSON/exit-code legs
        "passes.py": 85.0,      # per-pass clean + violating fixtures
        "__init__.py": 90.0,    # imported by every test
        "__main__.py": 0.0,     # separate-interpreter entry point only
    },
}
DEFAULT_FLOOR = 0.0  # un-exercised by this job's selection (see docstring)


def main(path: str) -> int:
    with open(path) as fh:
        data = json.load(fh)
    rows = []
    seen_groups = set()
    for fname, rec in sorted(data.get("files", {}).items()):
        norm = fname.replace(os.sep, "/")
        for prefix, floors in GROUPS.items():
            if prefix not in norm:
                continue
            seen_groups.add(prefix)
            base = norm.rsplit("/", 1)[-1]
            pct = float(rec["summary"]["percent_covered"])
            floor = floors.get(base, DEFAULT_FLOOR)
            rows.append((prefix + base, pct, floor))
            break
    missing = set(GROUPS) - seen_groups
    if missing:
        print("coverage_floor: no files in the report for group(s): "
              + ", ".join(sorted(missing)), file=sys.stderr)
        return 2
    failures = []
    print(f"{'file':<28} {'covered%':>9} {'floor%':>7}  status")
    for name, pct, floor in rows:
        ok = pct >= floor
        print(f"{name:<28} {pct:>8.1f} {floor:>7.1f}  "
              f"{'ok' if ok else 'BELOW FLOOR'}")
        if not ok:
            failures.append(name)
    if failures:
        print(f"coverage_floor: {len(failures)} file(s) regressed below "
              f"their floor: {', '.join(failures)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1]))
