#!/usr/bin/env bash
# Tuned process environment for the single-host serving stack.
#
#   tools/launch_env.sh python -m repro.launch.det_serve --workers 2 --shm
#   DET_HOST_DEVICES=4 tools/launch_env.sh python -m benchmarks.run
#
# Two knobs, both no-ops when unavailable so the wrapper is always safe:
#
# * tcmalloc: the serving front and its spawned workers allocate/free
#   large staging buffers on every batch; glibc malloc returns them to
#   the kernel and re-faults the pages.  If a tcmalloc build is present
#   on this host it is LD_PRELOADed (existing LD_PRELOAD preserved);
#   otherwise the stock allocator is used silently.
# * XLA host devices: DET_HOST_DEVICES=N appends
#   --xla_force_host_platform_device_count=N to XLA_FLAGS, carving the
#   CPU into N XLA devices — what the mesh/shard_map paths (and the CI
#   multi-device leg) need on a CPU-only host.
#
# The wrapper only exports environment and execs its argv: it never
# changes what the program computes, only how fast the allocator and
# how many host devices it sees.
set -eu

find_tcmalloc() {
    local cand
    for cand in \
        /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
        /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
        /usr/lib/libtcmalloc_minimal.so.4 \
        /usr/lib/libtcmalloc.so.4 \
        /usr/local/lib/libtcmalloc_minimal.so \
        /opt/conda/lib/libtcmalloc_minimal.so; do
        if [ -e "$cand" ]; then
            printf '%s' "$cand"
            return 0
        fi
    done
    return 1
}

if tcmalloc="$(find_tcmalloc)"; then
    export LD_PRELOAD="${LD_PRELOAD:+${LD_PRELOAD}:}${tcmalloc}"
    # large staging buffers are routine, not leaks — keep tcmalloc quiet
    export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="${TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD:-1099511627776}"
fi

if [ "${DET_HOST_DEVICES:-0}" -gt 0 ] 2>/dev/null; then
    export XLA_FLAGS="${XLA_FLAGS:+${XLA_FLAGS} }--xla_force_host_platform_device_count=${DET_HOST_DEVICES}"
fi

exec "$@"
