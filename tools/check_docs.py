#!/usr/bin/env python
"""Doc-consistency gate: README / DESIGN_* must not drift from the code.

The serving CLI and the design docs are maintained by hand, in
different files, by different PRs — the classic recipe for a README
that advertises a flag ``det_serve`` no longer has.  This gate makes
two narrow promises, checked statically on every CI run (the ``lint``
job, next to reprolint):

1. **Every ``--flag`` the docs attribute to ``det_serve`` exists** in
   ``src/repro/launch/det_serve.py``'s argparse.  "Attribute to" means
   the flag appears in a code span that also mentions ``det_serve`` —
   an inline backtick span, or one logical shell command inside a
   fenced block (backslash continuations joined).  Flags of *other*
   tools (``benchmarks/run.py --save``, reprolint's ``--json``,
   ``perf_serve --smoke``) live in spans without ``det_serve`` and are
   deliberately out of scope: this is a drift gate, not a universal
   flag registry.
2. **Every ``[[NAME]]`` cross-reference resolves** to ``NAME.md`` at
   the repo root.  The docs link each other with this wiki-style form
   (see README's architecture map); a rename that orphans a reference
   fails here instead of 404ing a reader.

Design constraints, same as reprolint (DESIGN_LINT.md): stdlib only
(the lint job runs before any wheel install), pure static analysis
(``ast`` for the argparse surface — never importing det_serve, which
would drag in jax), findings rendered ``file:line: message`` with a
non-zero exit.

Usage: ``python tools/check_docs.py [--root DIR]``
(``--root`` exists so the negative-path tests can point the gate at a
fixture tree instead of the live repo).
"""

import argparse
import ast
import re
import sys
from pathlib import Path

_FLAG_RE = re.compile(r"--[a-z][a-z0-9-]*")
_XREF_RE = re.compile(r"\[\[([A-Za-z0-9_]+)\]\]")
_SPAN_RE = re.compile(r"`([^`]+)`")

DET_SERVE_REL = Path("src") / "repro" / "launch" / "det_serve.py"


def argparse_flags(path: Path) -> set:
    """All ``--flag`` names det_serve's argparse accepts, via pure AST.

    Collects string constants starting with ``--`` in positional args
    of any ``*.add_argument(...)`` call — no import, no jax.
    """
    tree = ast.parse(path.read_text(), filename=str(path))
    flags = set()
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "add_argument"):
            continue
        for arg in node.args:
            if (isinstance(arg, ast.Constant)
                    and isinstance(arg.value, str)
                    and arg.value.startswith("--")):
                flags.add(arg.value)
    return flags


def iter_code_spans(text: str):
    """Yield ``(lineno, span_text)`` for every checkable code span.

    Outside fenced blocks: each inline ``\\`...`\\``` span, one per
    match.  Inside fenced blocks: one span per *logical command* —
    consecutive lines joined while they end with a backslash — so a
    wrapped ``det_serve`` invocation is judged as a whole and a
    ``pytest`` line sharing the block is not dragged into scope.
    """
    in_fence = False
    pending = []
    pending_line = 0
    for lineno, raw in enumerate(text.splitlines(), 1):
        stripped = raw.strip()
        if stripped.startswith("```"):
            if in_fence and pending:       # unterminated continuation
                yield pending_line, " ".join(pending)
                pending = []
            in_fence = not in_fence
            continue
        if in_fence:
            if not pending:
                pending_line = lineno
            pending.append(stripped.rstrip("\\").strip())
            if not stripped.endswith("\\"):
                yield pending_line, " ".join(pending)
                pending = []
        else:
            for m in _SPAN_RE.finditer(raw):
                yield lineno, m.group(1)
    if pending:                            # file ended mid-continuation
        yield pending_line, " ".join(pending)


def check_docs(root: Path) -> tuple:
    """Return ``(findings, stats)`` for the doc tree under ``root``."""
    findings = []
    det_serve = root / DET_SERVE_REL
    if not det_serve.exists():
        return [f"{DET_SERVE_REL}: missing (cannot check doc flags)"], {}
    flags = argparse_flags(det_serve)

    docs = sorted(root.glob("DESIGN_*.md"))
    readme = root / "README.md"
    if readme.exists():
        docs.insert(0, readme)
    else:
        findings.append("README.md: missing at repo root")

    n_spans = n_flags = n_refs = 0
    for doc in docs:
        text = doc.read_text()
        for lineno, span in iter_code_spans(text):
            if "det_serve" not in span:
                continue
            n_spans += 1
            for m in _FLAG_RE.finditer(span):
                n_flags += 1
                if m.group(0) not in flags:
                    findings.append(
                        f"{doc.name}:{lineno}: doc names det_serve flag "
                        f"{m.group(0)!r} but det_serve.py has no such "
                        f"argparse option")
        for lineno, raw in enumerate(text.splitlines(), 1):
            for m in _XREF_RE.finditer(raw):
                n_refs += 1
                target = root / (m.group(1) + ".md")
                if not target.exists():
                    findings.append(
                        f"{doc.name}:{lineno}: cross-reference "
                        f"[[{m.group(1)}]] does not resolve to "
                        f"{m.group(1)}.md at the repo root")
    stats = {"docs": len(docs), "det_serve_spans": n_spans,
             "flags_checked": n_flags, "xrefs_checked": n_refs,
             "argparse_flags": len(flags)}
    return findings, stats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="check README/DESIGN_* docs against det_serve's CLI")
    ap.add_argument("--root", default=None,
                    help="repo root to check (default: this file's repo)")
    args = ap.parse_args(argv)
    root = (Path(args.root) if args.root
            else Path(__file__).resolve().parent.parent)
    findings, stats = check_docs(root)
    for f in findings:
        print(f, file=sys.stderr)
    if stats:
        print("check_docs: {docs} docs, {det_serve_spans} det_serve "
              "spans, {flags_checked} flags vs {argparse_flags} argparse "
              "options, {xrefs_checked} cross-refs".format(**stats))
    if findings:
        print(f"check_docs: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    print("check_docs: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
